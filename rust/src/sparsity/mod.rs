//! Training-time sparsity: masks as first-class citizens of the
//! federated message path.
//!
//! The [`crate::pruning`] scorers (magnitude / Wanda / SymWanda / RIA /
//! stochRIA, with per-row, per-matrix and structured N:M selection
//! scopes) produce a keep-[`Mask`] — a bitset plus its cached support
//! indices — and this module turns that mask into a *run-wide wire
//! contract* enforced by the coordinator
//! ([`crate::coordinator::driver::Driver::with_mask`]):
//!
//! * **Lifecycle** ([`MaskState`]): masks are built once at init from
//!   the scorer config ([`MaskSpec`]) and the run's initial model,
//!   optionally refreshed every `refresh` rounds from the *current*
//!   server model (training-time re-pruning). Scoring calibration is
//!   gradient saliency: `a_in[c] = sum_r |g[r,c]|`,
//!   `a_out[r] = sum_c |g[r,c]|` from one full (or, for personalized
//!   masks, per-client) gradient at the build point — the training-time
//!   analogue of Wanda's activation norms. Stochastic scorers draw from
//!   deterministic per-client/per-epoch streams ([`mask_seed`]), so
//!   masked runs are bit-reproducible.
//! * **Scope**: one `global` mask shared by every node (FedComLoc-style
//!   sparse federated training — the server model lives in the support
//!   subspace for the whole run), or `personalized` per-client masks
//!   (FedP3-style: every client uplinks only its own support; the
//!   server model stays dense and so does the broadcast).
//! * **Enforcement** ([`masked_compress_add_into`]): every masked link
//!   payload is restricted to the support *before* compression — the
//!   compressor sees the compacted `nnz`-length vector, so Top-K /
//!   Rand-K select within the support and index widths shrink to
//!   `ceil(log2 nnz)`. Aggregation scatters back through the cached
//!   support (O(nnz), via the same [`SparseVec`] message type as the
//!   unmasked sparse fast path), never touching off-support
//!   coordinates.
//! * **Accounting** (SoteriaFL-style, booked by the driver): a masked
//!   dense payload costs `32 * nnz` bits (both ends know the mask, so
//!   only support values travel); a masked compressed payload costs
//!   whatever the compressor books *on the compacted input*; and the
//!   mask itself is charged — `dim` bits (one bitset) per receiving
//!   client on the downlink, once at build and again at every refresh.
//!
//! [`parse_method`] / [`parse_scope`] are the single string grammar for
//! pruning choices, shared by the `[sparsity]` TOML section
//! ([`crate::config`]) and the example CLIs.

use anyhow::{bail, Result};

use crate::compress::{Compressor, SparseVec};
use crate::oracle::Oracle;
use crate::pruning::{score, select_mask, Method, Scope};
use crate::Rng;

/// A keep-mask over `dim` model coordinates: a bitset for O(1)
/// membership plus the cached (sorted) support indices the masked
/// message path scatters through.
#[derive(Debug, Clone, PartialEq)]
pub struct Mask {
    words: Vec<u64>,
    support: Vec<u32>,
    dim: usize,
}

impl Mask {
    /// Build from a keep slice (`true` = coordinate stays trainable).
    pub fn from_keep(keep: &[bool]) -> Self {
        let dim = keep.len();
        let mut words = vec![0u64; dim.div_ceil(64)];
        let mut support = Vec::new();
        for (j, &k) in keep.iter().enumerate() {
            if k {
                words[j / 64] |= 1u64 << (j % 64);
                support.push(j as u32);
            }
        }
        Self { words, support, dim }
    }

    /// The all-kept mask (0% sparsity).
    pub fn full(dim: usize) -> Self {
        Self::from_keep(&vec![true; dim])
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Support size (kept coordinates).
    pub fn nnz(&self) -> usize {
        self.support.len()
    }

    /// Kept fraction nnz / dim.
    pub fn density(&self) -> f32 {
        self.support.len() as f32 / self.dim.max(1) as f32
    }

    pub fn is_kept(&self, j: usize) -> bool {
        (self.words[j / 64] >> (j % 64)) & 1 == 1
    }

    /// Sorted kept coordinate indices.
    pub fn support(&self) -> &[u32] {
        &self.support
    }

    /// Zero every off-support coordinate in place; returns how many
    /// nonzero entries were zeroed (same convention as
    /// [`crate::pruning::apply_mask`]).
    pub fn apply(&self, w: &mut [f32]) -> usize {
        debug_assert_eq!(w.len(), self.dim);
        let mut zeroed = 0;
        for (j, v) in w.iter_mut().enumerate() {
            if !self.is_kept(j) && *v != 0.0 {
                *v = 0.0;
                zeroed += 1;
            }
        }
        zeroed
    }

    /// On-wire bits of transmitting the mask itself: one bitset.
    pub fn wire_bits(&self) -> u64 {
        self.dim as u64
    }
}

/// Deterministic stream seed for mask construction: refresh epoch
/// `epoch`, client `client` (0 for the global mask) of the run seeded
/// with `seed`. Keys the stochastic scorers (stochRIA) so personalized
/// masks and refreshes are reproducible and order-free.
pub fn mask_seed(seed: u64, epoch: usize, client: usize) -> u64 {
    let mut h = seed ^ 0xD6E8FEB86659FD93u64.wrapping_mul(epoch as u64 + 1);
    h ^= 0xA24BAED4963EE407u64.wrapping_mul(client as u64 + 1);
    h
}

/// Scorer configuration of a masked run — the `[sparsity]` TOML section
/// ([`crate::config::build_mask_spec`]) resolved into pruning types.
#[derive(Debug, Clone)]
pub struct MaskSpec {
    /// Pruning score ([`crate::pruning::score`]). StochRIA's seed is
    /// overwritten at build time with a [`mask_seed`] stream.
    pub method: Method,
    /// Selection scope; [`Scope::StructuredNm`] ignores `sparsity`.
    pub scope: Scope,
    /// Fraction of coordinates pruned, in [0, 1).
    pub sparsity: f32,
    /// Matrix interpretation of the flat model for scoring: `rows`
    /// output rows of `dim / rows` inputs each (1 = one flat row, which
    /// makes per-row and per-matrix selection coincide).
    pub rows: usize,
    /// Rebuild the masks from the current server model every `refresh`
    /// rounds (training-time re-pruning); `None` = fixed masks.
    pub refresh: Option<usize>,
    /// FedP3-style per-client masks (scored on per-client gradients)
    /// instead of one global mask.
    pub personalized: bool,
}

impl Default for MaskSpec {
    fn default() -> Self {
        Self {
            method: Method::Magnitude,
            scope: Scope::PerMatrix,
            sparsity: 0.5,
            rows: 1,
            refresh: None,
            personalized: false,
        }
    }
}

impl MaskSpec {
    /// Dimension-aware validation (the dimension-free part happens at
    /// parse time in [`crate::config::build_mask_spec`]).
    pub fn validate(&self, dim: usize) -> Result<()> {
        anyhow::ensure!(
            (0.0..1.0).contains(&self.sparsity),
            "mask sparsity must be in [0, 1), got {}",
            self.sparsity
        );
        anyhow::ensure!(self.rows >= 1, "mask rows must be >= 1");
        anyhow::ensure!(
            dim % self.rows == 0,
            "mask rows = {} must divide the model dimension {dim}",
            self.rows
        );
        anyhow::ensure!(self.refresh != Some(0), "mask refresh must be >= 1 round");
        if let Scope::StructuredNm { n, m } = self.scope {
            anyhow::ensure!(n >= 1 && n <= m, "structured {n}:{m} must keep 1 <= n <= m");
        }
        Ok(())
    }
}

/// The resolved masks of one run: either one global mask or per-client
/// personalized masks.
#[derive(Debug, Clone, Default)]
pub struct MaskSet {
    global: Option<Mask>,
    per_client: Vec<Mask>,
}

impl MaskSet {
    /// The shared mask, when the run is not personalized. Broadcast
    /// payloads and tree-node re-compressions key off this (personalized
    /// runs keep those dense).
    pub fn global(&self) -> Option<&Mask> {
        self.global.as_ref()
    }

    /// The mask governing `client`'s uplink.
    pub fn mask_for(&self, client: usize) -> &Mask {
        match &self.global {
            Some(m) => m,
            None => &self.per_client[client],
        }
    }

    /// Per-receiver bits of distributing the masks (each client receives
    /// one `dim`-bit bitset, global and personalized alike).
    pub fn mask_wire_bits(&self) -> u64 {
        match &self.global {
            Some(m) => m.wire_bits(),
            None => self.per_client.first().map_or(0, Mask::wire_bits),
        }
    }

    /// Average support size (exact for global masks, mean over clients
    /// for personalized ones) — the `nnz` column of the reports.
    pub fn avg_nnz(&self) -> u64 {
        match &self.global {
            Some(m) => m.nnz() as u64,
            None => {
                let n = self.per_client.len().max(1) as u64;
                self.per_client.iter().map(|m| m.nnz() as u64).sum::<u64>() / n
            }
        }
    }
}

/// Per-run mask state owned by the driver: the spec, the resolved
/// [`MaskSet`], and the reusable scratch of the masked message path
/// (masked rounds allocate nothing at steady state).
pub struct MaskState {
    pub spec: MaskSpec,
    pub set: MaskSet,
    /// Compacted (support-gathered) input scratch.
    pub gather: Vec<f32>,
    /// Compacted dense-compress output scratch.
    pub cbuf: Vec<f32>,
    /// Sparse message scratch for paths whose caller provides none.
    pub sbuf: SparseVec,
    // build-time scratch
    grad: Vec<f32>,
    a_in: Vec<f32>,
    a_out: Vec<f32>,
}

impl MaskState {
    /// Build the run's masks from `spec` at model `x0` (refresh epoch 0).
    pub fn build(spec: &MaskSpec, oracle: &dyn Oracle, x0: &[f32], seed: u64) -> Result<Self> {
        let d = oracle.dim();
        spec.validate(d)?;
        let mut ms = Self {
            spec: spec.clone(),
            set: MaskSet::default(),
            gather: Vec::with_capacity(d),
            cbuf: Vec::with_capacity(d),
            sbuf: SparseVec::default(),
            grad: vec![0.0; d],
            a_in: Vec::new(),
            a_out: Vec::new(),
        };
        ms.rebuild(oracle, x0, seed, 0)?;
        Ok(ms)
    }

    /// Re-score and re-select every mask from the current model `x`
    /// (refresh epoch `epoch`; the caller books the mask re-transmission).
    pub fn rebuild(
        &mut self,
        oracle: &dyn Oracle,
        x: &[f32],
        seed: u64,
        epoch: usize,
    ) -> Result<()> {
        let d = oracle.dim();
        anyhow::ensure!(x.len() == d, "mask build point has dim {} != {d}", x.len());
        let o = self.spec.rows;
        let i = d / o;
        if self.spec.personalized {
            let n = oracle.n_clients();
            self.set.global = None;
            self.set.per_client.clear();
            for c in 0..n {
                oracle.loss_grad(c, x, &mut self.grad)?;
                let m = self.build_one(x, o, i, seed, epoch, c)?;
                self.set.per_client.push(m);
            }
        } else {
            oracle.full_loss_grad(x, &mut self.grad)?;
            let m = self.build_one(x, o, i, seed, epoch, 0)?;
            self.set.global = Some(m);
            self.set.per_client.clear();
        }
        Ok(())
    }

    /// Score `x` (as an `o x i` matrix) against the gradient-saliency
    /// calibration currently in `self.grad` and select one mask.
    fn build_one(
        &mut self,
        x: &[f32],
        o: usize,
        i: usize,
        seed: u64,
        epoch: usize,
        client: usize,
    ) -> Result<Mask> {
        self.a_in.clear();
        self.a_in.resize(i, 0.0);
        self.a_out.clear();
        self.a_out.resize(o, 0.0);
        for r in 0..o {
            for c in 0..i {
                let ag = self.grad[r * i + c].abs();
                self.a_in[c] += ag;
                self.a_out[r] += ag;
            }
        }
        let method = match self.spec.method {
            Method::StochRia { alpha, p, ratio, .. } => {
                Method::StochRia { alpha, p, ratio, seed: mask_seed(seed, epoch, client) }
            }
            m => m,
        };
        let scores = score(method, x, o, i, &self.a_in, &self.a_out);
        let keep = select_mask(&scores, o, i, self.spec.sparsity, self.spec.scope);
        let mask = Mask::from_keep(&keep);
        anyhow::ensure!(
            mask.nnz() > 0,
            "mask at sparsity {} keeps no coordinate",
            self.spec.sparsity
        );
        Ok(mask)
    }
}

/// The one masked compress-and-accumulate primitive every masked link
/// shares: gather `x` on the mask support, compress the compacted
/// payload, and scatter `scale * C(x|mask)` back through the support
/// into `dst` — O(nnz) end to end, off-support coordinates of `dst`
/// are never touched.
///
/// Three paths, mirroring the unmasked `compress_add_into`:
/// no compressor (support values travel raw: `32 * nnz` bits, direct
/// scatter), a native sparse form when `sparse` allows it (compacted
/// indices remapped through the support, O(k) [`SparseVec`] scatter),
/// or dense decompress over the compacted buffer + support scatter.
/// The sparse and dense paths consume identical RNG draws and book
/// identical bits (the compressor contract), and off-selected entries
/// of a dense compacted message are exact zeros — so masked-sparse and
/// masked-dense runs match bit for bit. Returns the payload's on-wire
/// bits (not booked).
#[allow(clippy::too_many_arguments)]
pub fn masked_compress_add_into(
    mask: &Mask,
    comp: Option<&dyn Compressor>,
    sparse: bool,
    x: &[f32],
    scale: f32,
    dst: &mut [f32],
    gather: &mut Vec<f32>,
    cbuf: &mut Vec<f32>,
    sbuf: &mut SparseVec,
    rng: &mut Rng,
) -> u64 {
    let sup = mask.support();
    gather.clear();
    gather.extend(sup.iter().map(|&j| x[j as usize]));
    let Some(c) = comp else {
        for (&j, &v) in sup.iter().zip(gather.iter()) {
            dst[j as usize] += scale * v;
        }
        return 32 * sup.len() as u64;
    };
    if sparse {
        if let Some(bits) = c.compress_sparse(gather, sbuf, rng) {
            // remap compacted indices to full model coordinates
            for idx in sbuf.idx.iter_mut() {
                *idx = sup[*idx as usize];
            }
            sbuf.dim = dst.len();
            sbuf.add_into(scale, dst);
            return bits;
        }
    }
    cbuf.clear();
    cbuf.resize(sup.len(), 0.0);
    let bits = c.compress(gather, cbuf, rng);
    for (&j, &v) in sup.iter().zip(cbuf.iter()) {
        dst[j as usize] += scale * v;
    }
    bits
}

/// Parse a pruning method name — the shared grammar of the `[sparsity]`
/// TOML section and the example CLIs. Accepts `magnitude | wanda |
/// symwanda | ria | stochria`, with parameters either inline
/// (`"symwanda(0.3)"` sets alpha, `"stochria(0.8)"` sets the subsample
/// ratio) or from the explicit `alpha` / `p` / `ratio` keys.
pub fn parse_method(
    name: &str,
    alpha: Option<f32>,
    p: Option<f32>,
    ratio: Option<f32>,
) -> Result<Method> {
    let (kind, inline) = match (name.find('('), name.ends_with(')')) {
        (Some(i), true) => (&name[..i], Some(&name[i + 1..name.len() - 1])),
        _ => (name, None),
    };
    let inline_f = |s: &str| -> Result<f32> {
        s.trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad numeric argument {s:?} in pruning method {name:?}"))
    };
    Ok(match kind {
        "magnitude" => Method::Magnitude,
        "wanda" => Method::Wanda,
        "symwanda" => {
            let a = match inline {
                Some(s) => inline_f(s)?,
                None => alpha.unwrap_or(0.5),
            };
            anyhow::ensure!((0.0..=1.0).contains(&a), "symwanda alpha must be in [0, 1], got {a}");
            Method::SymWanda { alpha: a }
        }
        "ria" => {
            let a = match inline {
                Some(s) => inline_f(s)?,
                None => alpha.unwrap_or(0.5),
            };
            Method::Ria { alpha: a, p: p.unwrap_or(0.5) }
        }
        "stochria" => {
            let r = match inline {
                Some(s) => inline_f(s)?,
                None => ratio.unwrap_or(0.5),
            };
            anyhow::ensure!(r > 0.0 && r <= 1.0, "stochria ratio must be in (0, 1], got {r}");
            Method::StochRia { alpha: alpha.unwrap_or(0.5), p: p.unwrap_or(0.5), ratio: r, seed: 0 }
        }
        other => bail!(
            "unknown pruning method {other:?} (known: magnitude | wanda | symwanda(alpha) | ria | stochria)"
        ),
    })
}

/// Parse a mask-selection scope: `per-row`, `per-matrix`, or an `n:m`
/// structured pattern (`"2:4"` keeps 2 of every 4 consecutive inputs
/// per row — the hardware-friendly semi-structured sparsity).
pub fn parse_scope(s: &str) -> Result<Scope> {
    match s {
        "per-row" => Ok(Scope::PerRow),
        "per-matrix" => Ok(Scope::PerMatrix),
        _ => {
            let Some((n, m)) = s.split_once(':') else {
                bail!("unknown pruning scope {s:?} (known: per-row | per-matrix | n:m, e.g. \"2:4\")");
            };
            let parse = |v: &str| -> Result<usize> {
                v.trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad structured-sparsity pattern {s:?}"))
            };
            let (n, m) = (parse(n)?, parse(m)?);
            anyhow::ensure!(n >= 1 && n <= m, "structured {n}:{m} must keep 1 <= n <= m");
            Ok(Scope::StructuredNm { n, m })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::topk::TopK;
    use crate::oracle::quadratic::QuadraticOracle;

    #[test]
    fn mask_from_keep_caches_support_and_bitset() {
        let m = Mask::from_keep(&[true, false, true, true, false]);
        assert_eq!(m.dim(), 5);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.support(), &[0, 2, 3]);
        assert!(m.is_kept(0) && !m.is_kept(1) && m.is_kept(3) && !m.is_kept(4));
        assert_eq!(m.wire_bits(), 5);
        let mut w = vec![1.0f32, 2.0, 0.0, 3.0, 4.0];
        assert_eq!(m.apply(&mut w), 2); // entries 1 and 4 (entry 2 was 0)
        assert_eq!(w, vec![1.0, 0.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn full_mask_keeps_everything() {
        let m = Mask::full(70); // spans a word boundary
        assert_eq!(m.nnz(), 70);
        assert!((0..70).all(|j| m.is_kept(j)));
        assert!((m.density() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn masked_dense_message_books_support_bits_and_scatters_o_nnz() {
        let m = Mask::from_keep(&[true, false, true, false]);
        let x = vec![1.0f32, 9.0, 2.0, 9.0];
        let mut dst = vec![0.0f32; 4];
        let (mut g, mut c, mut s) = (Vec::new(), Vec::new(), SparseVec::default());
        let bits = masked_compress_add_into(
            &m,
            None,
            true,
            &x,
            0.5,
            &mut dst,
            &mut g,
            &mut c,
            &mut s,
            &mut crate::rng(0),
        );
        assert_eq!(bits, 32 * 2);
        assert_eq!(dst, vec![0.5, 0.0, 1.0, 0.0]); // off-support untouched
    }

    #[test]
    fn masked_topk_selects_within_support_and_remaps() {
        // the largest-|x| coordinate is off-support: Top-1 must pick the
        // largest *kept* coordinate, with support-relative bit width
        let m = Mask::from_keep(&[true, false, true, true]);
        let x = vec![1.0f32, 100.0, -3.0, 2.0];
        let comp = TopK::new(1);
        let mut dst = vec![0.0f32; 4];
        let (mut g, mut c, mut s) = (Vec::new(), Vec::new(), SparseVec::default());
        let bits = masked_compress_add_into(
            &m,
            Some(&comp),
            true,
            &x,
            1.0,
            &mut dst,
            &mut g,
            &mut c,
            &mut s,
            &mut crate::rng(0),
        );
        assert_eq!(dst, vec![0.0, 0.0, -3.0, 0.0]);
        // 1 entry at nnz=3 index width (2 bits), not d=4 width
        assert_eq!(bits, crate::compress::sparse_bits(1, 3));
    }

    #[test]
    fn masked_sparse_and_dense_paths_match_bitwise() {
        let m = Mask::from_keep(&(0..32).map(|j| j % 3 != 0).collect::<Vec<_>>());
        let x: Vec<f32> = (0..32).map(|j| (j as f32 - 11.0) * 0.7).collect();
        let comp = TopK::new(4);
        let run = |sparse: bool| {
            let mut dst = vec![0.25f32; 32];
            let (mut g, mut c, mut s) = (Vec::new(), Vec::new(), SparseVec::default());
            let bits = masked_compress_add_into(
                &m,
                Some(&comp),
                sparse,
                &x,
                0.3,
                &mut dst,
                &mut g,
                &mut c,
                &mut s,
                &mut crate::rng(7),
            );
            (bits, dst)
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn mask_state_builds_global_and_personalized() {
        let mut rng = crate::rng(91);
        let q = QuadraticOracle::random(4, 16, 0.5, 2.0, 1.0, &mut rng);
        let x0 = vec![1.0f32; 16];
        let spec = MaskSpec {
            method: Method::SymWanda { alpha: 0.5 },
            sparsity: 0.5,
            ..MaskSpec::default()
        };
        let ms = MaskState::build(&spec, &q, &x0, 3).unwrap();
        let g = ms.set.global().expect("global mask");
        assert_eq!(g.nnz(), 8);
        assert_eq!(ms.set.avg_nnz(), 8);
        assert_eq!(ms.set.mask_wire_bits(), 16);

        let pspec = MaskSpec { personalized: true, ..spec };
        let pms = MaskState::build(&pspec, &q, &x0, 3).unwrap();
        assert!(pms.set.global().is_none());
        // heterogeneous clients score differently: at least one pair of
        // personalized masks must differ
        let distinct = (0..4).any(|i| pms.set.mask_for(i) != pms.set.mask_for(0));
        assert!(distinct, "personalized masks should differ across clients");
        // and rebuilding at the same point is deterministic
        let pms2 = MaskState::build(&pspec, &q, &x0, 3).unwrap();
        for i in 0..4 {
            assert_eq!(pms.set.mask_for(i), pms2.set.mask_for(i));
        }
    }

    #[test]
    fn mask_spec_validation_catches_bad_configs() {
        let mut rng = crate::rng(92);
        let q = QuadraticOracle::random(2, 10, 0.5, 2.0, 1.0, &mut rng);
        let x0 = vec![1.0f32; 10];
        let bad_sparsity = MaskSpec { sparsity: 1.0, ..MaskSpec::default() };
        assert!(MaskState::build(&bad_sparsity, &q, &x0, 0).is_err());
        let bad_rows = MaskSpec { rows: 3, ..MaskSpec::default() }; // 3 does not divide 10
        assert!(MaskState::build(&bad_rows, &q, &x0, 0).is_err());
        let bad_refresh = MaskSpec { refresh: Some(0), ..MaskSpec::default() };
        assert!(MaskState::build(&bad_refresh, &q, &x0, 0).is_err());
    }

    #[test]
    fn parse_method_grammar_and_errors() {
        assert_eq!(parse_method("magnitude", None, None, None).unwrap(), Method::Magnitude);
        assert_eq!(parse_method("wanda", None, None, None).unwrap(), Method::Wanda);
        assert_eq!(
            parse_method("symwanda(0.3)", None, None, None).unwrap(),
            Method::SymWanda { alpha: 0.3 }
        );
        assert_eq!(
            parse_method("symwanda", Some(0.7), None, None).unwrap(),
            Method::SymWanda { alpha: 0.7 }
        );
        assert_eq!(
            parse_method("ria", Some(1.0), Some(0.5), None).unwrap(),
            Method::Ria { alpha: 1.0, p: 0.5 }
        );
        assert!(matches!(
            parse_method("stochria(0.8)", None, None, None).unwrap(),
            Method::StochRia { ratio, .. } if (ratio - 0.8).abs() < 1e-6
        ));
        assert!(parse_method("optimal-brain-damage", None, None, None).is_err());
        assert!(parse_method("symwanda(huge)", None, None, None).is_err());
        assert!(parse_method("symwanda(2.0)", None, None, None).is_err());
    }

    #[test]
    fn parse_scope_grammar_and_errors() {
        assert_eq!(parse_scope("per-row").unwrap(), Scope::PerRow);
        assert_eq!(parse_scope("per-matrix").unwrap(), Scope::PerMatrix);
        assert_eq!(parse_scope("2:4").unwrap(), Scope::StructuredNm { n: 2, m: 4 });
        assert!(parse_scope("4:2").is_err()); // n > m
        assert!(parse_scope("0:4").is_err());
        assert!(parse_scope("rowwise").is_err());
        assert!(parse_scope("a:b").is_err());
    }

    #[test]
    fn mask_seed_streams_are_distinct_and_stable() {
        assert_eq!(mask_seed(5, 1, 2), mask_seed(5, 1, 2));
        assert_ne!(mask_seed(5, 1, 2), mask_seed(5, 1, 3));
        assert_ne!(mask_seed(5, 1, 2), mask_seed(5, 2, 2));
        assert_ne!(mask_seed(5, 1, 2), mask_seed(6, 1, 2));
    }
}
