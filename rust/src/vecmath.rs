//! Small dense f32 vector kernels used by every algorithm's hot loop.
//!
//! These are deliberately allocation-free: callers pass output buffers.
//! The compressor/aggregation path (the paper's L3 contribution) must not
//! allocate per round — see DESIGN.md §Perf.

/// y += a * x
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// y = x
pub fn copy(x: &[f32], y: &mut [f32]) {
    y.copy_from_slice(x);
}

/// x *= a
pub fn scale(a: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// <x, y>
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// ||x||^2
pub fn norm_sq(x: &[f32]) -> f32 {
    dot(x, x)
}

/// ||x||
pub fn norm(x: &[f32]) -> f32 {
    norm_sq(x).sqrt()
}

/// ||x - y||^2
pub fn dist_sq(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum()
}

/// out = x - y
pub fn sub(x: &[f32], y: &[f32], out: &mut [f32]) {
    for ((o, a), b) in out.iter_mut().zip(x).zip(y) {
        *o = a - b;
    }
}

/// out = x + y
pub fn add(x: &[f32], y: &[f32], out: &mut [f32]) {
    for ((o, a), b) in out.iter_mut().zip(x).zip(y) {
        *o = a + b;
    }
}

/// x = 0
pub fn zero(x: &mut [f32]) {
    x.fill(0.0);
}

/// Running mean accumulation: acc += x / n
pub fn acc_mean(x: &[f32], n: f32, acc: &mut [f32]) {
    axpy(1.0 / n, x, acc);
}

/// In-place convex combination: x = a*x + (1-a)*y
pub fn lerp(a: f32, x: &mut [f32], y: &[f32]) {
    for (xi, yi) in x.iter_mut().zip(y) {
        *xi = a * *xi + (1.0 - a) * yi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_dot_norm() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
        assert_eq!(dot(&x, &x), 14.0);
        assert!((norm(&x) - 14.0f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn sub_add_dist() {
        let x = vec![3.0, 4.0];
        let y = vec![1.0, 1.0];
        let mut o = vec![0.0; 2];
        sub(&x, &y, &mut o);
        assert_eq!(o, vec![2.0, 3.0]);
        add(&x, &y, &mut o);
        assert_eq!(o, vec![4.0, 5.0]);
        assert_eq!(dist_sq(&x, &y), 13.0);
    }

    #[test]
    fn lerp_endpoint() {
        let mut x = vec![2.0, 4.0];
        let y = vec![0.0, 0.0];
        lerp(0.5, &mut x, &y);
        assert_eq!(x, vec![1.0, 2.0]);
    }
}
