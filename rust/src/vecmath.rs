//! Dense f32 vector kernels under every algorithm's hot loop — written
//! so the round path is allocation-free *and* autovectorizes.
//!
//! Perf contract (DESIGN.md §Perf, upheld by `rust/tests/alloc_free.rs`):
//!
//! * **Allocation-free**: callers pass output buffers; nothing here
//!   allocates. Together with the compressors' reusable scratch and the
//!   coordinator's persistent buffers, a steady-state round performs
//!   zero heap allocations.
//! * **Unrolled for SIMD**: f32 addition is not associative, so a naive
//!   reduction loop pins the compiler to one serial dependency chain.
//!   [`dot`] (and through it [`norm_sq`]) accumulates in 4 independent
//!   lanes, and [`axpy`] is processed in 8-wide chunks, so LLVM can emit
//!   packed instructions. [`axpy4`] fuses four rank-1 updates into one
//!   pass over `y` (4x less write traffic) — the building block of the
//!   batched logistic-regression oracle's gradient accumulation
//!   (`oracle/logreg_rs.rs`).
//! * **O(k) sparse aggregation**: compressed messages bypass these dense
//!   kernels entirely — [`crate::compress::SparseVec::add_into`] scatters
//!   k entries instead of axpy-ing d. Dense kernels remain the reference
//!   semantics the sparse path must match bit-for-bit.

/// y += a * x (8-wide chunks; per-element arithmetic identical to the
/// naive loop, so results are bit-for-bit unchanged).
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut yc = y.chunks_exact_mut(8);
    let mut xc = x.chunks_exact(8);
    for (ys, xs) in yc.by_ref().zip(xc.by_ref()) {
        for j in 0..8 {
            ys[j] += a * xs[j];
        }
    }
    for (yi, xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += a * xi;
    }
}

/// y += a0*x0 + a1*x1 + a2*x2 + a3*x3 in one pass: a fused rank-4 update
/// that reads and writes `y` once for four accumulated rows.
pub fn axpy4(a: [f32; 4], x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], y: &mut [f32]) {
    let n = y.len();
    let (x0, x1, x2, x3) = (&x0[..n], &x1[..n], &x2[..n], &x3[..n]);
    for j in 0..n {
        y[j] += a[0] * x0[j] + a[1] * x1[j] + a[2] * x2[j] + a[3] * x3[j];
    }
}

/// y = x
pub fn copy(x: &[f32], y: &mut [f32]) {
    y.copy_from_slice(x);
}

/// x *= a
pub fn scale(a: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// <x, y>, accumulated in 4 independent lanes.
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f32; 4];
    let mut xc = x.chunks_exact(4);
    let mut yc = y.chunks_exact(4);
    for (a, b) in xc.by_ref().zip(yc.by_ref()) {
        acc[0] += a[0] * b[0];
        acc[1] += a[1] * b[1];
        acc[2] += a[2] * b[2];
        acc[3] += a[3] * b[3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (a, b) in xc.remainder().iter().zip(yc.remainder()) {
        s += a * b;
    }
    s
}

/// ||x||^2
pub fn norm_sq(x: &[f32]) -> f32 {
    dot(x, x)
}

/// ||x||
pub fn norm(x: &[f32]) -> f32 {
    norm_sq(x).sqrt()
}

/// ||x - y||^2, accumulated in 4 independent lanes.
pub fn dist_sq(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f32; 4];
    let mut xc = x.chunks_exact(4);
    let mut yc = y.chunks_exact(4);
    for (a, b) in xc.by_ref().zip(yc.by_ref()) {
        let (d0, d1, d2, d3) = (a[0] - b[0], a[1] - b[1], a[2] - b[2], a[3] - b[3]);
        acc[0] += d0 * d0;
        acc[1] += d1 * d1;
        acc[2] += d2 * d2;
        acc[3] += d3 * d3;
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (a, b) in xc.remainder().iter().zip(yc.remainder()) {
        let d = a - b;
        s += d * d;
    }
    s
}

/// out = x - y
pub fn sub(x: &[f32], y: &[f32], out: &mut [f32]) {
    for ((o, a), b) in out.iter_mut().zip(x).zip(y) {
        *o = a - b;
    }
}

/// out = x + y
pub fn add(x: &[f32], y: &[f32], out: &mut [f32]) {
    for ((o, a), b) in out.iter_mut().zip(x).zip(y) {
        *o = a + b;
    }
}

/// x = 0
pub fn zero(x: &mut [f32]) {
    x.fill(0.0);
}

/// Running mean accumulation: acc += x / n
pub fn acc_mean(x: &[f32], n: f32, acc: &mut [f32]) {
    axpy(1.0 / n, x, acc);
}

/// In-place convex combination: x = a*x + (1-a)*y
pub fn lerp(a: f32, x: &mut [f32], y: &[f32]) {
    for (xi, yi) in x.iter_mut().zip(y) {
        *xi = a * *xi + (1.0 - a) * yi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_dot_norm() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
        assert_eq!(dot(&x, &x), 14.0);
        assert!((norm(&x) - 14.0f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn sub_add_dist() {
        let x = vec![3.0, 4.0];
        let y = vec![1.0, 1.0];
        let mut o = vec![0.0; 2];
        sub(&x, &y, &mut o);
        assert_eq!(o, vec![2.0, 3.0]);
        add(&x, &y, &mut o);
        assert_eq!(o, vec![4.0, 5.0]);
        assert_eq!(dist_sq(&x, &y), 13.0);
    }

    #[test]
    fn lerp_endpoint() {
        let mut x = vec![2.0, 4.0];
        let y = vec![0.0, 0.0];
        lerp(0.5, &mut x, &y);
        assert_eq!(x, vec![1.0, 2.0]);
    }

    #[test]
    fn unrolled_kernels_cover_chunks_and_remainders() {
        // lengths straddling the 8-wide (axpy) and 4-wide (dot) chunking
        for n in [1usize, 3, 4, 7, 8, 9, 15, 16, 17, 33] {
            let x: Vec<f32> = (0..n).map(|i| i as f32 * 0.5 - 1.0).collect();
            let mut y: Vec<f32> = (0..n).map(|i| 1.0 - i as f32 * 0.25).collect();
            let mut y_ref = y.clone();
            axpy(0.75, &x, &mut y);
            for (yr, xi) in y_ref.iter_mut().zip(&x) {
                *yr += 0.75 * xi;
            }
            assert_eq!(y, y_ref, "axpy n={n}");
            let naive: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - naive).abs() < 1e-3, "dot n={n}");
            let naive_d: f32 = x.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum();
            assert!((dist_sq(&x, &y) - naive_d).abs() < 1e-2, "dist n={n}");
        }
    }

    #[test]
    fn axpy4_matches_four_axpys() {
        let n = 13;
        let rows: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..n).map(|i| (i as f32) * 0.1 + r as f32).collect())
            .collect();
        let a = [0.5f32, -1.0, 0.25, 2.0];
        let mut fused = vec![0.1f32; n];
        axpy4(a, &rows[0], &rows[1], &rows[2], &rows[3], &mut fused);
        let mut seq = vec![0.1f32; n];
        for j in 0..n {
            seq[j] += a[0] * rows[0][j] + a[1] * rows[1][j] + a[2] * rows[2][j] + a[3] * rows[3][j];
        }
        assert_eq!(fused, seq);
    }
}
