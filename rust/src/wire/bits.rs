//! LSB-first bit packing: the substrate every wire codec builds on.
//!
//! A [`BitWriter`] appends values at arbitrary widths (1..=64 bits) and
//! tracks the exact bit length — the number the codec invariant compares
//! against the [`crate::coordinator::CommLedger`] booking. Bit `i` of
//! the stream is bit `i % 8` of byte `i / 8`, so a stream is decoded by
//! a [`BitReader`] reading the same widths in the same order. The final
//! byte is zero-padded; the pad is framing overhead, never counted in
//! [`BitWriter::bit_len`].
//!
//! Readers are loud: running past the end of the buffer is an `anyhow`
//! error (the decoder robustness contract — truncated frames must never
//! panic or hang), and both ends reject widths outside 1..=64.

use anyhow::Result;

/// Append-only bit stream with exact bit accounting.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Pending bits not yet flushed to a full byte (LSB-first).
    acc: u128,
    used: u32,
    bit_len: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset to an empty stream, keeping the buffer capacity (the
    /// reusable-buffer idiom of the round hot path).
    pub fn clear(&mut self) {
        self.buf.clear();
        self.acc = 0;
        self.used = 0;
        self.bit_len = 0;
    }

    /// Append the low `width` bits of `value` (1..=64; higher bits of
    /// `value` must be zero).
    pub fn push(&mut self, value: u64, width: u32) {
        debug_assert!((1..=64).contains(&width), "bit width {width} outside 1..=64");
        debug_assert!(width == 64 || value >> width == 0, "value {value} overflows {width} bits");
        self.acc |= (value as u128) << self.used;
        self.used += width;
        self.bit_len += width as u64;
        while self.used >= 8 {
            self.buf.push(self.acc as u8);
            self.acc >>= 8;
            self.used -= 8;
        }
    }

    /// Append an f32 as its 32 raw bits.
    pub fn push_f32(&mut self, v: f32) {
        self.push(v.to_bits() as u64, 32);
    }

    /// Exact number of bits pushed so far — the codec side of the
    /// `codec bits == ledger bits` invariant.
    pub fn bit_len(&self) -> u64 {
        self.bit_len
    }

    /// Flush the trailing partial byte (zero-padded) and expose the
    /// byte stream. `bit_len` is unaffected by the pad.
    pub fn finish(&mut self) -> &[u8] {
        if self.used > 0 {
            self.buf.push(self.acc as u8);
            self.acc = 0;
            self.used = 0;
        }
        &self.buf
    }
}

/// Cursor over an LSB-first bit stream; every read is bounds-checked.
pub struct BitReader<'a> {
    buf: &'a [u8],
    byte: usize,
    acc: u128,
    avail: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, byte: 0, acc: 0, avail: 0 }
    }

    /// Read the next `width` bits (1..=64). Errors — never panics — when
    /// the stream ends early.
    pub fn read(&mut self, width: u32) -> Result<u64> {
        anyhow::ensure!((1..=64).contains(&width), "bit width {width} outside 1..=64");
        while self.avail < width {
            let b = *self
                .buf
                .get(self.byte)
                .ok_or_else(|| anyhow::anyhow!("bit stream truncated: {width}-bit read past end"))?;
            self.acc |= (b as u128) << self.avail;
            self.avail += 8;
            self.byte += 1;
        }
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        let v = (self.acc as u64) & mask;
        self.acc >>= width;
        self.avail -= width;
        Ok(v)
    }

    /// Read 32 bits as an f32.
    pub fn read_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.read(32)? as u32))
    }

    /// Bits consumed so far (pad included once read).
    pub fn bit_pos(&self) -> u64 {
        self.byte as u64 * 8 - self.avail as u64
    }

    /// Consume the rest of the stream, requiring it to be nothing but
    /// the final byte's zero pad (< 8 bits, all zero). Decoders that
    /// borrow a frame body straight out of a connection buffer call
    /// this after the last field: it turns "trailing garbage after a
    /// well-formed prefix" into a loud error instead of silently
    /// accepting a longer-than-quoted message.
    pub fn expect_zero_pad(&mut self) -> Result<()> {
        let total = self.buf.len() as u64 * 8;
        let rem = total - self.bit_pos();
        anyhow::ensure!(rem < 8, "{rem} unread bits where only a byte-alignment pad may remain");
        if rem > 0 {
            let pad = self.read(rem as u32)?;
            anyhow::ensure!(pad == 0, "nonzero pad bits 0b{pad:b} in the final byte");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_mixed_widths() {
        let mut w = BitWriter::new();
        let cases: Vec<(u64, u32)> = vec![
            (1, 1),
            (0, 1),
            (5, 3),
            (1023, 10),
            (u64::MAX, 64),
            (0xDEAD_BEEF, 32),
            (1, 64),
            (7, 7),
        ];
        let mut bits = 0u64;
        for &(v, width) in &cases {
            w.push(v, width);
            bits += width as u64;
        }
        assert_eq!(w.bit_len(), bits);
        let bytes = w.finish().to_vec();
        assert_eq!(bytes.len(), bits.div_ceil(8) as usize);
        let mut r = BitReader::new(&bytes);
        for &(v, width) in &cases {
            assert_eq!(r.read(width).unwrap(), v, "width {width}");
        }
    }

    #[test]
    fn f32_roundtrip_is_bitwise() {
        let mut w = BitWriter::new();
        let xs = [0.0f32, -0.0, 1.5, -3.25e-9, f32::MAX, f32::MIN_POSITIVE];
        for &x in &xs {
            w.push(1, 3); // misalign on purpose
            w.push_f32(x);
        }
        let bytes = w.finish().to_vec();
        let mut r = BitReader::new(&bytes);
        for &x in &xs {
            r.read(3).unwrap();
            assert_eq!(r.read_f32().unwrap().to_bits(), x.to_bits());
        }
    }

    #[test]
    fn truncated_read_errors_loudly() {
        let mut w = BitWriter::new();
        w.push(0b101, 3);
        let bytes = w.finish().to_vec();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3).unwrap(), 0b101);
        // the pad bits of the final byte are readable (zeros), but the
        // next full byte is not there
        assert!(r.read(64).is_err());
        let mut r2 = BitReader::new(&[]);
        assert!(r2.read(1).is_err());
    }

    #[test]
    fn zero_pad_check_accepts_pads_and_rejects_garbage() {
        let mut w = BitWriter::new();
        w.push(0b101, 3);
        let bytes = w.finish().to_vec();
        let mut r = BitReader::new(&bytes);
        r.read(3).unwrap();
        r.expect_zero_pad().unwrap();

        // an exactly byte-aligned stream has a zero-width pad
        let mut r = BitReader::new(&[0xAB]);
        r.read(8).unwrap();
        r.expect_zero_pad().unwrap();

        // a full unread byte is trailing garbage, not a pad
        let mut r = BitReader::new(&[0xAB, 0x00]);
        r.read(3).unwrap();
        assert!(r.expect_zero_pad().is_err());

        // nonzero pad bits are rejected
        let mut r = BitReader::new(&[0b1000_0101]);
        r.read(3).unwrap();
        assert!(r.expect_zero_pad().is_err());
    }

    #[test]
    fn clear_keeps_capacity() {
        let mut w = BitWriter::new();
        for i in 0..100u64 {
            w.push(i, 7);
        }
        w.finish();
        let cap = {
            w.clear();
            w.buf.capacity()
        };
        assert!(cap > 0);
        assert_eq!(w.bit_len(), 0);
    }
}
