//! Deterministic chaos injection at the coordinator's stream seam
//! (DESIGN.md §Faults).
//!
//! A [`ChaosConn`] wraps one accepted connection's reads and writes and
//! injects faults — connection drops, read stalls, write delays,
//! truncated writes, bit flips — drawn from dedicated RNG streams keyed
//! on `(seed, connection × generation, window, direction)`, a sibling
//! of [`crate::scenario::event_rng`] with its own mixing constants.
//! Decisions are keyed by **byte offsets**, not call counts: each
//! direction's byte stream is cut into [`CHUNK`]-byte windows, one fate
//! is drawn per window, and every read/write is capped at its window
//! boundary — so however the kernel chunks the actual I/O, a fault
//! lands at exactly the same byte offset on every replay of the same
//! seed. Since the frame bytes themselves are deterministic, a
//! drop-only composition cuts the stream at a reproducible frame
//! boundary and the run's losses and ledger replay bit for bit (the
//! chaos-smoke harness pins this).
//!
//! Fault semantics, drawn in priority order per window:
//! - **drop** (read): the connection dies — an injected
//!   `ConnectionReset` plus a real socket shutdown, so the remote
//!   client observes EOF and can take its reconnect path.
//! - **stall** (read): reads report `WouldBlock` for `stall_ms`; a
//!   stall longer than the serve timeout triggers the event loop's
//!   own deadline eviction.
//! - **flip** (read): one bit of the first byte read in the window is
//!   inverted — a corrupted frame that must die loudly in decode,
//!   never silently merge.
//! - **trunc** (write): a short write of at most 64 bytes, then the
//!   connection dies — the remote peer sees a frame cut mid-body.
//! - **delay** (write): writes report `WouldBlock` for `delay_ms`.
//!
//! A reconnected socket reuses its client id but bumps the connection
//! *generation*, giving the fresh socket fresh fault streams instead of
//! replaying the dead one's fate.

use std::io::{self, IoSlice};
use std::time::{Duration, Instant};

use crate::rng::Rng;

use super::net::{RecvBuf, Stream};

/// Fault-window size in bytes: one fate per `CHUNK` bytes per
/// direction, and no read or write crosses a window boundary.
pub const CHUNK: u64 = 4096;

/// Direction keys of the chaos streams.
pub const CH_READ: u64 = 0;
pub const CH_WRITE: u64 = 1;

/// One short-lived generator per fault decision — the chaos sibling of
/// [`crate::scenario::event_rng`], with distinct mixing constants and
/// rotation so the streams can never collide with the scenario's
/// event coins even under equal numeric keys.
pub fn chaos_rng(seed: u64, conn: u64, window: u64, dir: u64) -> Rng {
    let mut h = seed ^ 0xA076_1D64_78BD_642Fu64.wrapping_mul(conn.wrapping_add(1));
    h ^= 0xE703_7ED1_A0B4_28DBu64.wrapping_mul(window.wrapping_add(1));
    h ^= 0x8EBC_6AF0_9C88_C6E3u64.wrapping_mul(dir.wrapping_add(1));
    Rng::new(h.rotate_left(23))
}

/// Fault probabilities and timings, applied per [`CHUNK`]-byte window.
/// Programmatic only (the chaos fleet harness and tests); all-zero
/// means a chaos layer that passes every byte through untouched.
#[derive(Clone, Copy, Default, Debug)]
pub struct ChaosSpec {
    /// Per-read-window probability of killing the connection.
    pub drop: f32,
    /// Per-read-window probability of stalling reads for `stall_ms`.
    pub stall: f32,
    pub stall_ms: u64,
    /// Per-write-window probability of delaying writes for `delay_ms`.
    pub delay: f32,
    pub delay_ms: u64,
    /// Per-write-window probability of a truncated write followed by
    /// connection death.
    pub trunc: f32,
    /// Per-read-window probability of flipping one bit of the first
    /// byte read in the window.
    pub flip: f32,
    /// Seed of the chaos streams — one seed replays one fault schedule.
    pub seed: u64,
}

enum Fate {
    Pass,
    Drop,
    Stall,
    Delay,
    Trunc(usize),
    Flip,
}

fn reset_err() -> io::Error {
    io::Error::new(io::ErrorKind::ConnectionReset, "chaos: injected connection drop")
}

fn would_block() -> io::Error {
    io::Error::from(io::ErrorKind::WouldBlock)
}

/// Per-connection fault state: byte cursors per direction plus the
/// in-progress stall/delay clocks. Created per accepted connection
/// (and re-created with a bumped generation on reconnect).
pub struct ChaosConn {
    spec: ChaosSpec,
    /// `conn id << 20 | generation` — the connection key of every
    /// stream draw.
    key: u64,
    r_off: u64,
    w_off: u64,
    stall_until: Option<Instant>,
    delay_until: Option<Instant>,
    /// Windows whose stall/delay already ran to completion — re-drawing
    /// the same window's fate must not stall it twice.
    stall_served: u64,
    delay_served: u64,
    killed: bool,
}

impl ChaosConn {
    pub fn new(spec: ChaosSpec, conn: usize, generation: u64) -> ChaosConn {
        ChaosConn {
            spec,
            key: (conn as u64) << 20 | (generation & 0xF_FFFF),
            r_off: 0,
            w_off: 0,
            stall_until: None,
            delay_until: None,
            stall_served: u64::MAX,
            delay_served: u64::MAX,
            killed: false,
        }
    }

    fn fate(&self, dir: u64, window: u64) -> Fate {
        let mut rng = chaos_rng(self.spec.seed, self.key, window, dir);
        let s = &self.spec;
        if dir == CH_READ {
            if rng.bernoulli(s.drop) {
                return Fate::Drop;
            }
            if rng.bernoulli(s.stall) && window != self.stall_served {
                return Fate::Stall;
            }
            if rng.bernoulli(s.flip) {
                return Fate::Flip;
            }
        } else {
            if rng.bernoulli(s.trunc) {
                return Fate::Trunc(1 + rng.below(64));
            }
            if rng.bernoulli(s.delay) && window != self.delay_served {
                return Fate::Delay;
            }
        }
        Fate::Pass
    }

    fn kill(&mut self, stream: &Stream) {
        self.killed = true;
        // a real shutdown, so the remote peer observes EOF instead of
        // blocking on a socket the server merely stopped polling
        stream.shutdown();
    }

    /// Chaos-gated [`RecvBuf::fill`]: returns the fill result plus the
    /// number of faults this call injected.
    pub(crate) fn fill(
        &mut self,
        stream: &mut Stream,
        rbuf: &mut RecvBuf,
    ) -> (io::Result<usize>, u64) {
        if self.killed {
            return (Err(reset_err()), 0);
        }
        if let Some(t) = self.stall_until {
            if Instant::now() < t {
                return (Err(would_block()), 0);
            }
            self.stall_until = None;
        }
        let window = self.r_off / CHUNK;
        let fresh = self.r_off % CHUNK == 0;
        let mut flip = false;
        if fresh {
            match self.fate(CH_READ, window) {
                Fate::Drop => {
                    self.kill(stream);
                    return (Err(reset_err()), 1);
                }
                Fate::Stall => {
                    self.stall_served = window;
                    self.stall_until =
                        Some(Instant::now() + Duration::from_millis(self.spec.stall_ms));
                    return (Err(would_block()), 1);
                }
                Fate::Flip => flip = true,
                _ => {}
            }
        }
        let cap = (CHUNK - self.r_off % CHUNK) as usize;
        let r = rbuf.fill_max(stream, cap);
        let mut faults = 0u64;
        if let Ok(n) = r {
            if flip && n > 0 {
                rbuf.corrupt_tail(n);
                faults += 1;
            }
            self.r_off += n as u64;
        }
        (r, faults)
    }

    /// Chaos-gated vectored write: same contract as
    /// [`Stream::write_vectored`] plus the injected-fault count.
    pub(crate) fn write_vectored(
        &mut self,
        stream: &mut Stream,
        bufs: &[IoSlice<'_>],
    ) -> (io::Result<usize>, u64) {
        if self.killed {
            return (Err(reset_err()), 0);
        }
        if let Some(t) = self.delay_until {
            if Instant::now() < t {
                return (Err(would_block()), 0);
            }
            self.delay_until = None;
        }
        let window = self.w_off / CHUNK;
        let fresh = self.w_off % CHUNK == 0;
        let first = bufs.iter().find(|b| !b.is_empty()).map_or(&[][..], |b| &b[..]);
        if fresh {
            match self.fate(CH_WRITE, window) {
                Fate::Trunc(k) => {
                    // a short write, then the wire goes dead — the peer
                    // sees a frame cut mid-body
                    let k = k.min(first.len());
                    let r = if k == 0 { Ok(0) } else { stream.write(&first[..k]) };
                    if let Ok(n) = r {
                        self.w_off += n as u64;
                    }
                    self.kill(stream);
                    return (r, 1);
                }
                Fate::Delay => {
                    self.delay_served = window;
                    self.delay_until =
                        Some(Instant::now() + Duration::from_millis(self.spec.delay_ms));
                    return (Err(would_block()), 1);
                }
                _ => {}
            }
        }
        // cap at the window boundary so fault offsets replay exactly
        let remain = (CHUNK - self.w_off % CHUNK) as usize;
        let total: usize = bufs.iter().map(|b| b.len()).sum();
        let r = if total <= remain {
            stream.write_vectored(bufs)
        } else {
            stream.write(&first[..first.len().min(remain)])
        };
        if let Ok(n) = r {
            self.w_off += n as u64;
        }
        (r, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_streams_replay_per_seed() {
        for dir in [CH_READ, CH_WRITE] {
            for w in 0..32 {
                let a = chaos_rng(9, 3, w, dir).next_u64();
                let b = chaos_rng(9, 3, w, dir).next_u64();
                assert_eq!(a, b);
                assert_ne!(a, chaos_rng(10, 3, w, dir).next_u64());
                assert_ne!(a, chaos_rng(9, 4, w, dir).next_u64());
            }
        }
    }

    #[test]
    fn chaos_streams_differ_from_event_streams() {
        // sibling constants: equal numeric keys must not collide with
        // the scenario's event coins
        for k in 0..64u64 {
            let c = chaos_rng(7, k, k, CH_READ).next_u64();
            let e = crate::scenario::event_rng(7, k as usize, k as usize, k as usize).next_u64();
            assert_ne!(c, e);
        }
    }

    #[test]
    fn generation_gets_fresh_streams() {
        let a = ChaosConn::new(ChaosSpec { drop: 0.5, seed: 1, ..Default::default() }, 2, 0);
        let b = ChaosConn::new(ChaosSpec { drop: 0.5, seed: 1, ..Default::default() }, 2, 1);
        let fates_a: Vec<bool> =
            (0..64).map(|w| matches!(a.fate(CH_READ, w), Fate::Drop)).collect();
        let fates_b: Vec<bool> =
            (0..64).map(|w| matches!(b.fate(CH_READ, w), Fate::Drop)).collect();
        assert_ne!(fates_a, fates_b);
    }
}
