//! Per-message-kind bit packing, pinned to the [`CommLedger`] formulas.
//!
//! Every encoder here produces a stream whose [`BitWriter::bit_len`]
//! equals, by construction, the bits the ledger books for that payload
//! (DESIGN.md §Wire; property-tested in rust/tests/wire.rs):
//!
//! - **Dense** — 32 bits per entry (`Identity`: `32 * d`).
//! - **Sparse** — `k * (32 + ceil(log2 d))` for a [`SparseVec`] of `k`
//!   pairs over dimension `d`, exactly [`sparse_bits`]`(k, d)`: indices
//!   packed at log2(d) width, values as raw f32 bits, pair order
//!   preserved (Top-K / Rand-K emit order is part of the message).
//! - **Masked raw** — `32 * nnz`: support known on both ends, only the
//!   values travel, in support order.
//! - **Masked sparse** — compressor output over the compacted support:
//!   `k * (32 + ceil(log2 nnz))` with support-relative indices, mapped
//!   back to global coordinates on decode.
//! - **QSGD** — 32-bit norm + `max(1, ceil(log2(2s+1)))` bits per entry;
//!   [`qsgd_encode`] *is* the quantizer (it replicates
//!   [`Qsgd::compress`]'s arithmetic and rng draws bit-for-bit, then
//!   packs sign+level codes instead of f32s).
//! - **PermK** — 64-bit shared round seed + 32 bits per kept value; the
//!   block indices are re-derived from the seed on decode.
//! - **Anchor delta** — the downlink sibling of the sparse layout:
//!   `m * (32 + ceil(log2 d))` for `m` changed anchor coordinates, each
//!   carried as its global index plus the coordinate's **new** raw f32
//!   bits (not a difference — exact bit replacement, so a client anchor
//!   can never drift from the server's). Indices are strictly
//!   ascending; `m == 0` is legal (an unchanged anchor costs 0 bits).
//!   The driver books `min(dense_bits(d), anchor_delta_bits(m, d))`
//!   per receiver and falls back to a dense resync when delta would
//!   not win (DESIGN.md §Wire, delta broadcast).
//!
//! Decoders validate everything they read (index ranges, level codes,
//! lengths) and return `anyhow` errors on malformed input — never a
//! panic (see the fuzz tests in rust/tests/wire.rs).
//!
//! [`CommLedger`]: crate::coordinator::CommLedger
//! [`sparse_bits`]: crate::compress::sparse_bits
//! [`Qsgd::compress`]: crate::compress::quantize::Qsgd

use anyhow::{bail, ensure, Result};

use super::bits::{BitReader, BitWriter};
use crate::compress::{permk::PermK, SparseVec};
use crate::Rng;

/// Packed index width for dimension `d`: ceil(log2 d), min 1 — the
/// width [`crate::compress::sparse_bits`] charges per index.
pub fn idx_width(d: usize) -> u32 {
    usize::BITS - (d.max(2) - 1).leading_zeros()
}

/// QSGD per-entry code width for `levels` levels: sign+level in
/// `max(1, ceil(log2(2s+1)))` bits — the width `Qsgd::compress` quotes.
pub fn qsgd_entry_width(levels: u32) -> u32 {
    (32 - (2 * levels).leading_zeros().min(31)).max(1)
}

/// MSG body layouts of the `wire::net` frame grammar. The layout byte
/// travels in every ROUND (negotiated) and MSG (echoed) frame; it picks
/// which codec above packs/unpacks the body.
pub const LAYOUT_SPARSE: u8 = 0;
pub const LAYOUT_MASKED_RAW: u8 = 1;
pub const LAYOUT_MASKED_SPARSE: u8 = 2;

/// Exact bit cost of a MSG body: the number the client's compressor
/// quoted and the [`crate::coordinator::CommLedger`] books — recomputed
/// server-side from the frame header alone, so a peer cannot lie about
/// its own size.
pub fn wire_body_bits(layout: u8, k: usize, dim: usize, sup_len: usize) -> Result<u64> {
    Ok(match layout {
        LAYOUT_SPARSE => {
            ensure!(k >= 1 && k <= dim, "sparse payload of {k} pairs over dim {dim}");
            crate::compress::sparse_bits(k, dim)
        }
        LAYOUT_MASKED_RAW => {
            ensure!(
                k == sup_len && k >= 1,
                "masked raw payload must cover the support exactly ({k} != {sup_len})"
            );
            32 * k as u64
        }
        LAYOUT_MASKED_SPARSE => {
            ensure!(
                k >= 1 && k <= sup_len,
                "masked sparse payload of {k} pairs over a support of {sup_len}"
            );
            crate::compress::sparse_bits(k, sup_len)
        }
        other => bail!("unknown wire layout {other}"),
    })
}

/// Decode one MSG body — borrowed straight out of a connection's
/// receive buffer, no per-frame copy — into `sv` (global indices) and
/// return its exact wire bits. The body must be exactly
/// `ceil(bits / 8)` bytes and its final-byte pad must be zero: trailing
/// garbage after a well-formed prefix is a protocol error, not free
/// riding.
pub fn decode_wire_body(
    layout: u8,
    k: usize,
    body: &[u8],
    dim: usize,
    sup: &[u32],
    sv: &mut SparseVec,
) -> Result<u64> {
    let bits = wire_body_bits(layout, k, dim, sup.len())?;
    ensure!(
        body.len() as u64 == bits.div_ceil(8),
        "MSG body is {} bytes; layout {layout} with {k} pairs packs {bits} bits ({} bytes)",
        body.len(),
        bits.div_ceil(8)
    );
    let mut r = BitReader::new(body);
    match layout {
        LAYOUT_SPARSE => decode_sparse(&mut r, dim, k, sv)?,
        LAYOUT_MASKED_RAW => decode_masked_raw(&mut r, dim, sup, sv)?,
        LAYOUT_MASKED_SPARSE => decode_masked_sparse(&mut r, dim, sup, k, sv)?,
        _ => unreachable!("layout validated by wire_body_bits"),
    }
    r.expect_zero_pad()?;
    Ok(bits)
}

/// Encode a dense f32 run at 32 bits per entry.
pub fn encode_dense(x: &[f32], w: &mut BitWriter) {
    for &v in x {
        w.push_f32(v);
    }
}

/// Decode `len` dense f32 entries into `out` (cleared first).
pub fn decode_dense(r: &mut BitReader, len: usize, out: &mut Vec<f32>) -> Result<()> {
    out.clear();
    out.reserve(len);
    for _ in 0..len {
        out.push(r.read_f32()?);
    }
    Ok(())
}

/// Encode a [`SparseVec`] as `k` (index, value) pairs, indices at
/// [`idx_width`]`(dim)`. Bit length is exactly `sparse_bits(k, dim)`.
pub fn encode_sparse(sv: &SparseVec, w: &mut BitWriter) -> Result<()> {
    let iw = idx_width(sv.dim);
    for (&i, &v) in sv.idx.iter().zip(&sv.val) {
        ensure!((i as usize) < sv.dim, "sparse index {i} out of range for dim {}", sv.dim);
        w.push(i as u64, iw);
        w.push_f32(v);
    }
    Ok(())
}

/// Decode `k` (index, value) pairs over dimension `dim` into `out`
/// (cleared first); rejects out-of-range indices.
pub fn decode_sparse(r: &mut BitReader, dim: usize, k: usize, out: &mut SparseVec) -> Result<()> {
    let iw = idx_width(dim);
    out.clear(dim);
    for _ in 0..k {
        let i = r.read(iw)?;
        ensure!((i as usize) < dim, "sparse index {i} out of range for dim {dim}");
        let v = r.read_f32()?;
        out.push(i as u32, v);
    }
    Ok(())
}

/// Encode a masked no-compressor payload: the values of `sv` in
/// support order, 32 bits each (`32 * nnz`; `sv` must cover the whole
/// support, which the fused emit path guarantees).
pub fn encode_masked_raw(sv: &SparseVec, sup: &[u32], w: &mut BitWriter) -> Result<()> {
    ensure!(
        sv.len() == sup.len(),
        "masked raw payload has {} values for a support of {}",
        sv.len(),
        sup.len()
    );
    for &v in &sv.val {
        w.push_f32(v);
    }
    Ok(())
}

/// Decode a masked no-compressor payload: one f32 per support index,
/// re-attached to the global coordinates in `sup`.
pub fn decode_masked_raw(
    r: &mut BitReader,
    dim: usize,
    sup: &[u32],
    out: &mut SparseVec,
) -> Result<()> {
    out.clear(dim);
    for &g in sup {
        ensure!((g as usize) < dim, "support index {g} out of range for dim {dim}");
        out.push(g, r.read_f32()?);
    }
    Ok(())
}

/// Encode a compressed masked payload: `sv` holds *global* indices (the
/// fused emit convention); each is mapped to its position in the sorted
/// support and packed at [`idx_width`]`(nnz)` — exactly the
/// `sparse_bits(k, nnz)` the ledger books for compression within the
/// support.
pub fn encode_masked_sparse(sv: &SparseVec, sup: &[u32], w: &mut BitWriter) -> Result<()> {
    let iw = idx_width(sup.len());
    for (&g, &v) in sv.idx.iter().zip(&sv.val) {
        let c = sup
            .binary_search(&g)
            .map_err(|_| anyhow::anyhow!("masked index {g} not in the support"))?;
        w.push(c as u64, iw);
        w.push_f32(v);
    }
    Ok(())
}

/// Decode `k` support-relative pairs, mapping each compact index back
/// through `sup` to its global coordinate.
pub fn decode_masked_sparse(
    r: &mut BitReader,
    dim: usize,
    sup: &[u32],
    k: usize,
    out: &mut SparseVec,
) -> Result<()> {
    let iw = idx_width(sup.len());
    out.clear(dim);
    for _ in 0..k {
        let c = r.read(iw)? as usize;
        let g = *sup.get(c).ok_or_else(|| {
            anyhow::anyhow!("masked index {c} out of range for support of {}", sup.len())
        })?;
        ensure!((g as usize) < dim, "support index {g} out of range for dim {dim}");
        let v = r.read_f32()?;
        out.push(g, v);
    }
    Ok(())
}

/// Exact bit cost of an anchor delta over `m` changed coordinates of a
/// `d`-dimensional anchor: `m * (32 + idx_width(d))` — what the
/// [`crate::coordinator::CommLedger`] books per delta-mode receiver
/// (the frame's version/count header travels unbooked, like every
/// other frame header).
pub fn anchor_delta_bits(m: usize, d: usize) -> u64 {
    m as u64 * (32 + idx_width(d) as u64)
}

/// Encode an anchor delta: for each changed coordinate (strictly
/// ascending), its global index at [`idx_width`]`(anchor.len())` plus
/// the coordinate's **new** value as raw f32 bits. Bit length is
/// exactly [`anchor_delta_bits`]`(coords.len(), anchor.len())`.
pub fn encode_anchor_delta(coords: &[u32], anchor: &[f32], w: &mut BitWriter) -> Result<()> {
    let d = anchor.len();
    let iw = idx_width(d);
    let mut prev: Option<u32> = None;
    for &i in coords {
        ensure!((i as usize) < d, "delta index {i} out of range for dim {d}");
        ensure!(
            prev.is_none_or(|p| p < i),
            "delta indices must be strictly ascending (saw {i} after {prev:?})"
        );
        prev = Some(i);
        w.push(i as u64, iw);
        w.push_f32(anchor[i as usize]);
    }
    Ok(())
}

/// Decode `m` anchor-delta pairs straight into `anchor`, overwriting
/// each changed coordinate with its streamed f32 bits. Rejects
/// out-of-range and non-ascending indices loudly — a corrupted delta
/// must never silently desync a client anchor.
pub fn decode_anchor_delta(r: &mut BitReader, m: usize, anchor: &mut [f32]) -> Result<()> {
    let d = anchor.len();
    let iw = idx_width(d);
    let mut prev: Option<u32> = None;
    for _ in 0..m {
        let i = r.read(iw)?;
        ensure!((i as usize) < d, "delta index {i} out of range for dim {d}");
        let i = i as u32;
        ensure!(
            prev.is_none_or(|p| p < i),
            "delta indices must be strictly ascending (saw {i} after {prev:?})"
        );
        prev = Some(i);
        anchor[i as usize] = r.read_f32()?;
    }
    Ok(())
}

/// Quantize-and-pack: replicates `Qsgd::compress`'s arithmetic and rng
/// draws exactly (same norm, same stochastic rounding, same draw count)
/// but emits sign+level codes at [`qsgd_entry_width`] instead of f32s.
/// Bit length is exactly the compressor's quote:
/// `32 + len * qsgd_entry_width(levels)`.
///
/// Level-0 codes are canonicalized to positive sign, so decode yields
/// `+0.0` where the float path may carry `-0.0` — numerically equal,
/// and invisible to the `+=` scatter the server replays into.
pub fn qsgd_encode(levels: u32, x: &[f32], rng: &mut Rng, w: &mut BitWriter) {
    let s = levels as f32;
    let ew = qsgd_entry_width(levels);
    let nx = crate::vecmath::norm(x);
    w.push_f32(nx);
    if nx == 0.0 {
        // Qsgd::compress zero-fills without touching the rng; the code
        // for level 0 is `levels` (positive sign).
        for _ in 0..x.len() {
            w.push(levels as u64, ew);
        }
    } else {
        for &v in x {
            let u = v.abs() / nx * s; // in [0, s]
            let l = u.floor();
            let p = u - l;
            let level = if rng.f32_unit() < p { l + 1.0 } else { l };
            let lv = level as u32;
            let code = if lv == 0 || !v.is_sign_negative() { levels + lv } else { levels - lv };
            w.push(code as u64, ew);
        }
    }
}

/// Decode `len` QSGD codes back to the quantized grid: each entry is
/// `sign * norm * level / s` in `Qsgd::compress`'s exact f32 op order.
pub fn qsgd_decode(r: &mut BitReader, levels: u32, len: usize, out: &mut Vec<f32>) -> Result<()> {
    let s = levels as f32;
    let ew = qsgd_entry_width(levels);
    let nx = r.read_f32()?;
    ensure!(nx.is_finite() && nx >= 0.0, "qsgd norm {nx} is not a finite non-negative value");
    out.clear();
    out.reserve(len);
    for _ in 0..len {
        let code = r.read(ew)?;
        ensure!(code <= 2 * levels as u64, "qsgd code {code} exceeds 2*levels = {}", 2 * levels);
        let signed = code as i64 - levels as i64;
        let sign = if signed < 0 { -1.0f32 } else { 1.0 };
        let level = signed.unsigned_abs() as f32;
        out.push(sign * nx * level / s);
    }
    Ok(())
}

/// Encode a PermK block: the shared round seed (64 bits) plus the kept
/// values in block order (32 bits each) — `64 + 32 * kept`, the
/// compressor's quote. `sv` must be `comp.compress_sparse` output for
/// the same dimension (indices are checked against the derived block).
pub fn permk_encode(comp: &PermK, sv: &SparseVec, w: &mut BitWriter) -> Result<()> {
    let block = comp.block(sv.dim);
    ensure!(
        sv.idx == block,
        "PermK payload indices do not match the block derived from seed {:#x}",
        comp.round_seed
    );
    w.push(comp.round_seed, 64);
    for &v in &sv.val {
        w.push_f32(v);
    }
    Ok(())
}

/// Decode a PermK block for worker `worker` of `n`: re-derives the
/// permutation from the streamed seed and re-attaches indices in the
/// identical block order.
pub fn permk_decode(
    r: &mut BitReader,
    n: usize,
    worker: usize,
    dim: usize,
    out: &mut SparseVec,
) -> Result<()> {
    ensure!(n >= 1 && worker < n, "PermK worker {worker} out of range for n = {n}");
    let seed = r.read(64)?;
    let block = PermK::new(n, worker, seed).block(dim);
    out.clear(dim);
    for g in block {
        out.push(g, r.read_f32()?);
    }
    Ok(())
}
