//! Readiness multiplexing for the networked coordinator: a minimal,
//! std-only `poll(2)` wrapper (DESIGN.md §Wire).
//!
//! The event-driven server in [`super::net`] needs exactly three things
//! from the OS: "which of these sockets can make progress", "wake me no
//! later than this deadline", and a listener whose address can be
//! rebound immediately by the next test run. None of that justifies a
//! dependency — `poll(2)` is POSIX, its ABI is three integers and a
//! flat array, and the crate policy (ROADMAP) is std-only. [`Poller`]
//! owns one reusable descriptor array: callers re-register the sockets
//! they care about each lap (`clear` + `push`), `wait` blocks until
//! readiness or timeout, and `readiness(slot)` reports the i-th pushed
//! descriptor's state. Registration order is the caller's own index
//! space — no opaque tokens.
//!
//! On non-Unix hosts there is no `poll`; the fallback `wait` sleeps
//! briefly and reports every registered descriptor ready per its
//! interest. That is *spurious* readiness, which is safe — every socket
//! the server registers is non-blocking, so a wrong "ready" costs one
//! `WouldBlock` syscall, degrading the event loop to a slow poll loop
//! rather than breaking it.

use std::io;
use std::time::Duration;

/// Raw descriptor handle registered with a [`Poller`]. An alias for the
/// platform `RawFd` on Unix; a placeholder integer elsewhere (the
/// fallback poller never dereferences it).
#[cfg(unix)]
pub type RawFd = std::os::unix::io::RawFd;
#[cfg(not(unix))]
pub type RawFd = i32;

/// What a registered descriptor waits for.
#[derive(Clone, Copy, Default)]
pub struct Interest {
    pub read: bool,
    pub write: bool,
}

/// What the kernel reported for one registered descriptor.
#[derive(Clone, Copy, Default)]
pub struct Readiness {
    pub readable: bool,
    pub writable: bool,
    /// Error, hangup, or invalid descriptor — the owner should read it
    /// to observe the actual error/EOF and retire the connection.
    pub closed: bool,
}

#[cfg(unix)]
mod sys {
    /// `struct pollfd` — identical layout on every POSIX platform.
    #[repr(C)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    /// `nfds_t`: `unsigned long` on Linux/glibc, `unsigned int` on the
    /// BSD family.
    #[cfg(target_os = "linux")]
    pub type NfdsT = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    pub type NfdsT = u32;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: i32) -> i32;
    }
}

/// A reusable `poll(2)` descriptor set. `clear` + `push` rebuild the
/// set each event-loop lap (registration is just a Vec write — no
/// kernel state to keep in sync), `wait` blocks, `readiness(i)` reads
/// the i-th pushed descriptor's result.
#[derive(Default)]
pub struct Poller {
    #[cfg(unix)]
    fds: Vec<sys::PollFd>,
    #[cfg(not(unix))]
    interests: Vec<Interest>,
}

impl Poller {
    pub fn new() -> Self {
        Self::default()
    }
}

#[cfg(unix)]
impl Poller {
    pub fn clear(&mut self) {
        self.fds.clear();
    }

    pub fn push(&mut self, fd: RawFd, interest: Interest) {
        let mut events = 0i16;
        if interest.read {
            events |= sys::POLLIN;
        }
        if interest.write {
            events |= sys::POLLOUT;
        }
        self.fds.push(sys::PollFd { fd, events, revents: 0 });
    }

    /// Block until at least one descriptor is ready or `timeout`
    /// passes; returns how many are ready (0 on timeout). `EINTR`
    /// retries with the full timeout — callers re-check their deadlines
    /// every lap, so a signal can only stretch one wait, never a
    /// deadline.
    pub fn wait(&mut self, timeout: Duration) -> io::Result<usize> {
        let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        loop {
            let rc = unsafe { sys::poll(self.fds.as_mut_ptr(), self.fds.len() as sys::NfdsT, ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let e = io::Error::last_os_error();
            if e.kind() != io::ErrorKind::Interrupted {
                return Err(e);
            }
        }
    }

    pub fn readiness(&self, slot: usize) -> Readiness {
        let r = self.fds[slot].revents;
        Readiness {
            readable: r & sys::POLLIN != 0,
            writable: r & sys::POLLOUT != 0,
            closed: r & (sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0,
        }
    }
}

#[cfg(not(unix))]
impl Poller {
    pub fn clear(&mut self) {
        self.interests.clear();
    }

    pub fn push(&mut self, _fd: RawFd, interest: Interest) {
        self.interests.push(interest);
    }

    /// Fallback without `poll`: nap briefly, then report everything
    /// ready per its interest (spurious readiness — see module docs).
    pub fn wait(&mut self, timeout: Duration) -> io::Result<usize> {
        std::thread::sleep(timeout.min(Duration::from_millis(1)));
        Ok(self.interests.len())
    }

    pub fn readiness(&self, slot: usize) -> Readiness {
        let i = self.interests[slot];
        Readiness { readable: i.read, writable: i.write, closed: false }
    }
}

/// Bind a TCP listener with `SO_REUSEADDR` set *before* `bind`, so
/// back-to-back test/bench runs reusing a fixed port don't flake on
/// `TIME_WAIT` remnants (std's `TcpListener::bind` never sets it). The
/// raw-socket path covers IPv4 on Unix with a 1024-deep accept backlog;
/// anything else (IPv6, non-Unix, or a raw-path failure) falls back to
/// the portable std bind.
pub fn bind_tcp_reuseaddr(hostport: &str) -> io::Result<std::net::TcpListener> {
    #[cfg(unix)]
    {
        use std::net::ToSocketAddrs;
        let addrs: Vec<std::net::SocketAddr> = hostport.to_socket_addrs()?.collect();
        for a in &addrs {
            if let std::net::SocketAddr::V4(v4) = a {
                if let Ok(l) = bind_v4_reuseaddr(v4) {
                    return Ok(l);
                }
            }
        }
    }
    std::net::TcpListener::bind(hostport)
}

#[cfg(unix)]
fn bind_v4_reuseaddr(addr: &std::net::SocketAddrV4) -> io::Result<std::net::TcpListener> {
    use std::os::unix::io::FromRawFd;
    const AF_INET: i32 = 2;
    const SOCK_STREAM: i32 = 1;
    #[cfg(target_os = "linux")]
    const SOL_SOCKET: i32 = 1;
    #[cfg(target_os = "linux")]
    const SO_REUSEADDR: i32 = 2;
    #[cfg(not(target_os = "linux"))]
    const SOL_SOCKET: i32 = 0xffff;
    #[cfg(not(target_os = "linux"))]
    const SO_REUSEADDR: i32 = 0x0004;
    extern "C" {
        fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        fn setsockopt(fd: i32, level: i32, name: i32, val: *const u8, len: u32) -> i32;
        fn bind(fd: i32, addr: *const u8, len: u32) -> i32;
        fn listen(fd: i32, backlog: i32) -> i32;
        fn close(fd: i32) -> i32;
    }
    let fd = unsafe { socket(AF_INET, SOCK_STREAM, 0) };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    let fail = |fd: i32| -> io::Error {
        let e = io::Error::last_os_error();
        unsafe { close(fd) };
        e
    };
    let one: i32 = 1;
    if unsafe { setsockopt(fd, SOL_SOCKET, SO_REUSEADDR, (&one as *const i32).cast(), 4) } < 0 {
        return Err(fail(fd));
    }
    // struct sockaddr_in, hand-packed (16 bytes): family, big-endian
    // port, big-endian address, 8 zero bytes of padding. BSD kernels
    // read a leading length byte where Linux has a 16-bit family.
    let mut sa = [0u8; 16];
    #[cfg(target_os = "linux")]
    sa[..2].copy_from_slice(&(AF_INET as u16).to_ne_bytes());
    #[cfg(not(target_os = "linux"))]
    {
        sa[0] = 16;
        sa[1] = AF_INET as u8;
    }
    sa[2..4].copy_from_slice(&addr.port().to_be_bytes());
    sa[4..8].copy_from_slice(&addr.ip().octets());
    if unsafe { bind(fd, sa.as_ptr(), 16) } < 0 {
        return Err(fail(fd));
    }
    if unsafe { listen(fd, 1024) } < 0 {
        return Err(fail(fd));
    }
    Ok(unsafe { std::net::TcpListener::from_raw_fd(fd) })
}

/// Raise this process's open-file soft limit toward its hard limit and
/// return the resulting soft limit. A 1024-client serve needs roughly
/// three descriptors per client when fleet and coordinator share one
/// process (server socket + the client's read/write handle pair), which
/// blows straight through the common 1024 default — tests and the
/// serve-smoke example call this first so the scaling story doesn't
/// depend on shell `ulimit` incantations. Best-effort: on failure the
/// current limit is returned unchanged (non-Unix: a large placeholder).
pub fn raise_nofile_limit() -> u64 {
    #[cfg(unix)]
    {
        #[repr(C)]
        struct RLimit {
            cur: u64,
            max: u64,
        }
        #[cfg(target_os = "linux")]
        const RLIMIT_NOFILE: i32 = 7;
        #[cfg(not(target_os = "linux"))]
        const RLIMIT_NOFILE: i32 = 8;
        extern "C" {
            fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
            fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
        }
        let mut lim = RLimit { cur: 0, max: 0 };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
            return 0;
        }
        if lim.cur >= lim.max {
            return lim.cur;
        }
        // macOS rejects NOFILE soft limits above OPEN_MAX even when the
        // reported hard limit is RLIM_INFINITY; step down once
        for cur in [lim.max, lim.max.min(10_240)] {
            let want = RLimit { cur, max: lim.max };
            if unsafe { setrlimit(RLIMIT_NOFILE, &want) } == 0 {
                return cur;
            }
        }
        lim.cur
    }
    #[cfg(not(unix))]
    {
        u64::MAX
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn poll_reports_written_bytes_readable() {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let mut tx = std::net::TcpStream::connect(addr).unwrap();
        let (mut rx, _) = l.accept().unwrap();

        let mut p = Poller::new();
        p.clear();
        p.push(rx.as_raw_fd(), Interest { read: true, write: false });
        // nothing written yet: a short wait times out with 0 ready
        let n = p.wait(Duration::from_millis(10)).unwrap();
        assert_eq!(n, 0, "unwritten socket must not be readable");

        tx.write_all(b"ping").unwrap();
        tx.flush().unwrap();
        p.clear();
        p.push(rx.as_raw_fd(), Interest { read: true, write: false });
        let n = p.wait(Duration::from_secs(5)).unwrap();
        assert_eq!(n, 1);
        assert!(p.readiness(0).readable);
        let mut buf = [0u8; 4];
        rx.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"ping");
    }

    #[test]
    fn reuseaddr_listener_accepts_and_rebinds() {
        let l = bind_tcp_reuseaddr("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let mut tx = std::net::TcpStream::connect(addr).unwrap();
        let (mut rx, _) = l.accept().unwrap();
        tx.write_all(b"ok").unwrap();
        let mut buf = [0u8; 2];
        rx.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"ok");
        // the whole point: the same port rebinds immediately
        drop((tx, rx, l));
        let again = bind_tcp_reuseaddr(&addr.to_string()).unwrap();
        drop(again);
    }
}
