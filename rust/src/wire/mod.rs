//! The wire layer: bit-packed message codecs and the networked
//! coordinator (DESIGN.md §Wire).
//!
//! Everything the simulator previously *accounted* (the
//! [`crate::coordinator::CommLedger`]'s bit formulas) this module
//! *materializes*: [`bits`] is the LSB-first packing substrate,
//! [`codec`] encodes every registry message kind at exactly the bit
//! cost the ledger books (`encode(msg).bit_len() == booked bits`, the
//! codec invariant), and [`net`] streams those bytes between a socket
//! client fleet and the driver's fused O(k) merge — so a networked
//! `fedeff serve --listen` run reproduces the in-process run bit for
//! bit while sending real, countable bytes. [`evloop`] is the std-only
//! readiness substrate under [`net`]: a raw `poll(2)` wrapper plus the
//! socket/rlimit syscalls the event loop needs, no async runtime.
//! [`chaos`] injects deterministic faults (drops, stalls, delays,
//! truncations, bit flips) at the stream seam under [`net`], keyed by
//! byte offsets on seeded streams so a fault schedule replays
//! bit-identically per seed (DESIGN.md §Faults).

pub mod bits;
pub mod chaos;
pub mod codec;
pub mod evloop;
pub mod net;
