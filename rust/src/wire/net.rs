//! The networked coordinator: real bytes between a socket fleet and the
//! fused O(k) merge (DESIGN.md §Wire).
//!
//! `fedeff serve --listen ADDR` binds a [`NetServer`] (TCP loopback or
//! a Unix domain socket; addresses are `tcp:HOST:PORT` / `uds:PATH`),
//! accepts one length-framed connection per dataset client, and drives
//! the same [`crate::coordinator::driver::Driver`] round loop as an
//! in-process run — with the client pipeline executing on the other
//! end of the sockets. A [`NetTransport`] implements the driver's
//! fused-uplink seam: it broadcasts each round's recipe (anchor, seed,
//! scale, payload, mask support) as ROUND frames and then reads one MSG
//! frame per (cohort client, channel) **in cohort order**, decoding the
//! bit-packed body straight into the driver's sparse scatter
//! ([`crate::algorithms::api::RoundCtx`]'s uplink replay) — the server
//! never materializes a cohort·d dense staging buffer, and the booked
//! bits come from the same formulas the compressors quote, so a
//! networked run reproduces the in-process fused run **bit for bit**
//! (losses, bits_up, bits_down; pinned by rust/tests/serve_net.rs and
//! the serve-smoke CI job at 256 clients).
//!
//! Frame layout (little-endian): `u32 len | u8 kind | payload`, where
//! `len` counts the kind byte plus the payload and is capped at
//! [`MAX_FRAME`]. Kinds: HELLO (client joins: id, fleet size, dim),
//! ROUND (server→client round recipe), MSG (client→server one uplink
//! channel: round, channel, layout, pair count, bit-packed codec body,
//! zero-padded to bytes), DONE (server→fleet shutdown). Malformed,
//! truncated or oversized frames produce `anyhow` errors and a closed
//! connection — never a panic, and never a hang (every socket carries a
//! read timeout).
//!
//! Backpressure: the server reads MSG frames in cohort order with one
//! bounded [`BufReader`]/[`BufWriter`] pair per connection; a client
//! only ever has one round in flight (it cannot produce a second
//! message until the next ROUND frame arrives), so per-connection
//! memory is O(k) userspace plus the kernel socket buffers.

use std::cell::RefCell;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use super::bits::{BitReader, BitWriter};
use super::codec;
use crate::algorithms::build_algorithm;
use crate::algorithms::RunOptions;
use crate::compress::SparseVec;
use crate::config::{build_driver, compressor_by_name, Spec};
use crate::coordinator::fused::{run_chunk, FusedKit, FusedPayload};
use crate::coordinator::{FusedUplink, PoolInput, WorkerOut};
use crate::data::synth::Heterogeneity;
use crate::metrics::{RoundStat, RunRecord};
use crate::oracle::logreg_rs::RustLogReg;
use crate::oracle::Oracle;

/// Hard ceiling on one frame's size (kind byte + payload): 64 MiB.
pub const MAX_FRAME: u32 = 1 << 26;
/// Userspace buffer per connection half (the bounded backpressure
/// window; everything beyond it waits in the kernel socket buffer).
const CONN_BUF: usize = 64 * 1024;
/// Default socket read timeout — a peer that stops mid-frame errors
/// out instead of hanging the round loop.
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(30);

const KIND_HELLO: u8 = 1;
const KIND_ROUND: u8 = 2;
const KIND_MSG: u8 = 3;
const KIND_DONE: u8 = 4;

const LAYOUT_SPARSE: u8 = 0;
const LAYOUT_MASKED_RAW: u8 = 1;
const LAYOUT_MASKED_SPARSE: u8 = 2;

const PAYLOAD_GRADIENT: u8 = 0;
const PAYLOAD_LOCAL_SGD: u8 = 1;

// ---------------------------------------------------------------------
// address grammar + stream/listener abstraction
// ---------------------------------------------------------------------

/// One connected byte stream (TCP or, on Unix, a domain socket).
pub enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
            #[cfg(unix)]
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
        })
    }

    fn set_read_timeout(&self, t: Duration) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(Some(t))?,
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(Some(t))?,
        }
        Ok(())
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// A bound accept socket. `tcp:HOST:PORT` binds TCP (port 0 picks a
/// free port — read the real one back from [`Listener::local_addr`]);
/// `uds:PATH` binds a Unix domain socket (stale socket files are
/// replaced).
pub enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    pub fn bind(addr: &str) -> Result<Listener> {
        if let Some(hostport) = addr.strip_prefix("tcp:") {
            let l = TcpListener::bind(hostport)
                .with_context(|| format!("binding tcp listener on {hostport}"))?;
            return Ok(Listener::Tcp(l));
        }
        if let Some(path) = addr.strip_prefix("uds:") {
            #[cfg(unix)]
            {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .with_context(|| format!("binding unix socket {path}"))?;
                return Ok(Listener::Unix(l));
            }
            #[cfg(not(unix))]
            bail!("uds: addresses need a Unix platform; use tcp:HOST:PORT");
        }
        bail!("address {addr:?} is neither tcp:HOST:PORT nor uds:PATH")
    }

    /// The canonical address peers connect to (resolves `tcp:...:0` to
    /// the picked port).
    pub fn local_addr(&self) -> Result<String> {
        Ok(match self {
            Listener::Tcp(l) => format!("tcp:{}", l.local_addr()?),
            #[cfg(unix)]
            Listener::Unix(l) => {
                let a = l.local_addr()?;
                let p = a.as_pathname().context("unix listener has no pathname")?;
                format!("uds:{}", p.display())
            }
        })
    }

    fn accept(&self) -> Result<Stream> {
        Ok(match self {
            Listener::Tcp(l) => Stream::Tcp(l.accept()?.0),
            #[cfg(unix)]
            Listener::Unix(l) => Stream::Unix(l.accept()?.0),
        })
    }
}

/// Connect to a `tcp:`/`uds:` address.
pub fn connect(addr: &str) -> Result<Stream> {
    if let Some(hostport) = addr.strip_prefix("tcp:") {
        return Ok(Stream::Tcp(
            TcpStream::connect(hostport).with_context(|| format!("connecting to {hostport}"))?,
        ));
    }
    if let Some(path) = addr.strip_prefix("uds:") {
        #[cfg(unix)]
        return Ok(Stream::Unix(
            UnixStream::connect(path).with_context(|| format!("connecting to {path}"))?,
        ));
        #[cfg(not(unix))]
        bail!("uds: addresses need a Unix platform; use tcp:HOST:PORT");
    }
    bail!("address {addr:?} is neither tcp:HOST:PORT nor uds:PATH")
}

/// [`connect`] with retries while the server is still binding/accepting
/// (the fleet usually races the coordinator's startup).
fn connect_retry(addr: &str, budget: Duration) -> Result<Stream> {
    let t0 = std::time::Instant::now();
    loop {
        match connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if t0.elapsed() < budget => {
                let _ = e;
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => return Err(e),
        }
    }
}

// ---------------------------------------------------------------------
// frames
// ---------------------------------------------------------------------

/// One connection: buffered reader/writer halves over cloned handles.
struct Conn {
    r: BufReader<Stream>,
    w: BufWriter<Stream>,
}

impl Conn {
    fn new(s: Stream, timeout: Duration) -> Result<Conn> {
        s.set_read_timeout(timeout)?;
        let rh = s.try_clone()?;
        Ok(Conn {
            r: BufReader::with_capacity(CONN_BUF, rh),
            w: BufWriter::with_capacity(CONN_BUF, s),
        })
    }
}

fn write_frame(w: &mut impl Write, kind: u8, payload: &[u8]) -> Result<()> {
    let len = payload.len() as u64 + 1;
    ensure!(len <= MAX_FRAME as u64, "frame of {len} bytes exceeds MAX_FRAME ({MAX_FRAME})");
    w.write_all(&(len as u32).to_le_bytes())?;
    w.write_all(&[kind])?;
    w.write_all(payload)?;
    Ok(())
}

/// Read one frame into `buf` (payload only); returns the kind byte.
/// Zero-length and oversized frames are protocol errors.
fn read_frame(r: &mut impl Read, buf: &mut Vec<u8>) -> Result<u8> {
    let mut hdr = [0u8; 4];
    r.read_exact(&mut hdr).context("reading frame header")?;
    let len = u32::from_le_bytes(hdr);
    ensure!(len >= 1, "zero-length frame");
    ensure!(len <= MAX_FRAME, "oversized frame: {len} bytes (max {MAX_FRAME})");
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind).context("reading frame kind")?;
    buf.clear();
    buf.resize(len as usize - 1, 0);
    r.read_exact(buf).context("reading frame payload")?;
    Ok(kind[0])
}

/// Bounds-checked little-endian cursor over a frame payload.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).context("frame length overflow")?;
        ensure!(
            end <= self.buf.len(),
            "frame truncated: wanted {n} bytes past offset {}",
            self.pos
        );
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn rest(self) -> &'a [u8] {
        &self.buf[self.pos..]
    }

    fn done(&self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "{} trailing bytes in frame",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------
// shared spec plumbing (the config path `run`, `serve` and the fleet
// all resolve identically — satellite fix for the serve dataset bug)
// ---------------------------------------------------------------------

/// Build the pure-Rust logreg oracle a spec describes — the exact
/// dataset construction `fedeff run` uses (profile, clients,
/// heterogeneity, regularizer, seed), so server, fleet and in-process
/// comparisons all train on identical data.
pub fn fleet_oracle(spec: &Spec) -> Result<RustLogReg> {
    let ds = &spec.dataset;
    ensure!(ds.kind == "logreg", "networked serving drives the logreg substrate, not {}", ds.kind);
    let het = match ds.heterogeneity.as_deref() {
        Some("iid") => Heterogeneity::Iid,
        Some("class") => Heterogeneity::ClassSkew(0.85),
        _ => Heterogeneity::FeatureShift(0.5),
    };
    let (d, m) = crate::data::synth::logreg_profile(&ds.profile)
        .ok_or_else(|| anyhow::anyhow!("unknown logreg profile {}", ds.profile))?;
    let mut rng = crate::rng(spec.experiment.seed);
    let data = crate::data::synth::logreg_dataset(d, m, ds.clients, het, 0.3, &mut rng);
    Ok(RustLogReg::new(data, ds.reg))
}

/// The effective leaf (client-out) uplink compressor of a spec —
/// mirrors the driver's resolution (a `[links.up.l0]` edge under an
/// executed tree overrides the flat `[compressor] up`).
pub fn leaf_compressor(spec: &Spec) -> Option<(String, usize, usize)> {
    if spec.topology.as_ref().is_some_and(|t| t.levels.is_some()) {
        if let Some(Some(e)) = spec.links.up_edges.first() {
            return Some((e.kind.clone(), e.k, e.k_prime));
        }
    }
    spec.links.up.as_ref().map(|u| (u.clone(), spec.links.k, spec.links.k_prime))
}

/// [`RunOptions`] a spec describes (the serve path's view).
fn spec_opts(spec: &Spec) -> RunOptions {
    RunOptions {
        rounds: spec.experiment.rounds,
        eval_every: spec.experiment.eval_every,
        seed: spec.experiment.seed,
        ..Default::default()
    }
}

/// Run a spec in-process on the fused worker-pool path, streaming eval
/// rounds — the reference a networked run must match bit for bit.
pub fn run_in_process(spec: &Spec, on_eval: &mut dyn FnMut(&RoundStat)) -> Result<RunRecord> {
    let oracle = fleet_oracle(spec)?;
    let d = oracle.dim();
    let mut alg = build_algorithm(&spec.algorithm, &oracle)?;
    let driver = build_driver(spec, spec.dataset.clients)?;
    let x0 = vec![0.5f32; d];
    driver.run_parallel_streaming(alg.as_mut(), &oracle, &x0, &spec_opts(spec), |r| on_eval(r))
}

// ---------------------------------------------------------------------
// server
// ---------------------------------------------------------------------

/// Decode scratch + per-round state behind [`NetTransport`]'s interior
/// mutability (the driver's fused seam takes `&self`).
struct NetState {
    input: PoolInput,
    sup: Vec<u32>,
    round: usize,
    layout: u8,
    frame: Vec<u8>,
    body: Vec<u8>,
    sv: SparseVec,
}

/// The driver-facing side of an accepted fleet: implements the fused
/// uplink seam over one framed connection per client.
pub struct NetTransport {
    conns: RefCell<Vec<Conn>>,
    dim: usize,
    has_comp: bool,
    st: RefCell<NetState>,
}

impl NetTransport {
    /// Broadcast DONE and flush — the fleet's clean-shutdown signal.
    pub fn shutdown(&self) -> Result<()> {
        let mut conns = self.conns.borrow_mut();
        for c in conns.iter_mut() {
            write_frame(&mut c.w, KIND_DONE, &[])?;
            c.w.flush()?;
        }
        Ok(())
    }
}

impl FusedUplink for NetTransport {
    fn fused_dispatch(
        &self,
        cohort: &[usize],
        _groups: Option<&[usize]>,
        fill: &mut dyn FnMut(&mut PoolInput),
    ) -> Result<()> {
        let mut st = self.st.borrow_mut();
        let st = &mut *st;
        st.input.cohort.clear();
        st.input.cohort.extend_from_slice(cohort);
        fill(&mut st.input);
        let inp = &st.input;
        ensure!(inp.point.len() == self.dim, "round anchor has the wrong dimension");
        ensure!(inp.scales.len() == cohort.len(), "round scales do not cover the cohort");
        let layout = if inp.sup.is_empty() {
            ensure!(self.has_comp, "an unmasked networked round needs an uplink compressor");
            LAYOUT_SPARSE
        } else if self.has_comp {
            LAYOUT_MASKED_SPARSE
        } else {
            LAYOUT_MASKED_RAW
        };
        st.layout = layout;
        st.round = inp.round;
        st.sup.clear();
        st.sup.extend_from_slice(&inp.sup);

        // one shared ROUND body; only the 4 scale bytes differ per client
        let b = &mut st.body;
        b.clear();
        b.extend_from_slice(&u32::try_from(inp.round).context("round exceeds u32")?.to_le_bytes());
        b.extend_from_slice(&inp.seed.to_le_bytes());
        let scale_off = b.len();
        b.extend_from_slice(&0f32.to_le_bytes());
        b.push(layout);
        match inp.payload {
            FusedPayload::Gradient => b.push(PAYLOAD_GRADIENT),
            FusedPayload::LocalSgd { steps, lr, prox_mu } => {
                b.push(PAYLOAD_LOCAL_SGD);
                b.extend_from_slice(
                    &u32::try_from(steps).context("local steps exceed u32")?.to_le_bytes(),
                );
                b.extend_from_slice(&lr.to_le_bytes());
                match prox_mu {
                    Some(mu) => {
                        b.push(1);
                        b.extend_from_slice(&mu.to_le_bytes());
                    }
                    None => b.push(0),
                }
            }
            FusedPayload::Scaffold { .. } => bail!(
                "stateful (Scaffold) payloads cannot be served over the wire: the control \
                 rows live in server memory"
            ),
            FusedPayload::None => bail!("networked round dispatched without a payload recipe"),
        }
        b.extend_from_slice(&(self.dim as u32).to_le_bytes());
        for &v in &inp.point {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.extend_from_slice(&(inp.sup.len() as u32).to_le_bytes());
        for &j in &inp.sup {
            b.extend_from_slice(&j.to_le_bytes());
        }

        let mut conns = self.conns.borrow_mut();
        for (p, &client) in cohort.iter().enumerate() {
            b[scale_off..scale_off + 4].copy_from_slice(&inp.scales[p].to_le_bytes());
            let conn = conns
                .get_mut(client)
                .with_context(|| format!("cohort client {client} has no connection"))?;
            write_frame(&mut conn.w, KIND_ROUND, b)
                .with_context(|| format!("sending ROUND to client {client}"))?;
            conn.w.flush().with_context(|| format!("flushing ROUND to client {client}"))?;
        }
        Ok(())
    }

    fn fused_visit(
        &self,
        cohort: &[usize],
        channels: usize,
        visit: &mut dyn FnMut(usize, usize, &[u32], &[f32], u64) -> Result<()>,
    ) -> Result<()> {
        let mut st = self.st.borrow_mut();
        let st = &mut *st;
        let mut conns = self.conns.borrow_mut();
        for &client in cohort {
            let conn = conns
                .get_mut(client)
                .with_context(|| format!("cohort client {client} has no connection"))?;
            for ch in 0..channels {
                let kind = read_frame(&mut conn.r, &mut st.frame)
                    .with_context(|| format!("reading channel {ch} from client {client}"))?;
                ensure!(kind == KIND_MSG, "client {client} sent frame kind {kind}, expected MSG");
                let mut cur = Cur::new(&st.frame);
                let round = cur.u32()? as usize;
                let mch = cur.u8()? as usize;
                let layout = cur.u8()?;
                let k = cur.u32()? as usize;
                let body = cur.rest();
                ensure!(
                    round == st.round && mch == ch && layout == st.layout,
                    "client {client} answered (round {round}, ch {mch}, layout {layout}); \
                     expected (round {}, ch {ch}, layout {})",
                    st.round,
                    st.layout
                );
                let bits = decode_msg_body(layout, k, body, self.dim, &st.sup, &mut st.sv)
                    .with_context(|| format!("decoding client {client} channel {ch}"))?;
                visit(client, ch, &st.sv.idx, &st.sv.val, bits)?;
            }
        }
        Ok(())
    }
}

/// Decode one MSG body into `sv` (global indices) and return its exact
/// wire bits — by construction the same number the client's compressor
/// quoted, which is what the ledger books.
fn decode_msg_body(
    layout: u8,
    k: usize,
    body: &[u8],
    dim: usize,
    sup: &[u32],
    sv: &mut SparseVec,
) -> Result<u64> {
    let bits = match layout {
        LAYOUT_SPARSE => {
            ensure!(k >= 1 && k <= dim, "sparse payload of {k} pairs over dim {dim}");
            crate::compress::sparse_bits(k, dim)
        }
        LAYOUT_MASKED_RAW => {
            ensure!(
                k == sup.len() && k >= 1,
                "masked raw payload must cover the support exactly ({k} != {})",
                sup.len()
            );
            32 * k as u64
        }
        LAYOUT_MASKED_SPARSE => {
            ensure!(
                k >= 1 && k <= sup.len(),
                "masked sparse payload of {k} pairs over a support of {}",
                sup.len()
            );
            crate::compress::sparse_bits(k, sup.len())
        }
        other => bail!("unknown wire layout {other}"),
    };
    ensure!(
        body.len() as u64 == bits.div_ceil(8),
        "MSG body is {} bytes; layout {layout} with {k} pairs packs {bits} bits ({} bytes)",
        body.len(),
        bits.div_ceil(8)
    );
    let mut r = BitReader::new(body);
    match layout {
        LAYOUT_SPARSE => codec::decode_sparse(&mut r, dim, k, sv)?,
        LAYOUT_MASKED_RAW => codec::decode_masked_raw(&mut r, dim, sup, sv)?,
        LAYOUT_MASKED_SPARSE => codec::decode_masked_sparse(&mut r, dim, sup, k, sv)?,
        _ => unreachable!(),
    }
    Ok(bits)
}

/// A bound coordinator endpoint. [`NetServer::bind`] first (so tests
/// and scripts can read the real port before starting a fleet), then
/// [`NetServer::serve`] a spec against it.
pub struct NetServer {
    listener: Listener,
    /// Socket read timeout applied to every accepted connection.
    pub timeout: Duration,
}

impl NetServer {
    pub fn bind(addr: &str) -> Result<NetServer> {
        Ok(NetServer { listener: Listener::bind(addr)?, timeout: DEFAULT_TIMEOUT })
    }

    /// The canonical connect address (resolves `tcp:...:0`).
    pub fn local_addr(&self) -> Result<String> {
        self.listener.local_addr()
    }

    /// Accept HELLO handshakes until all `n` client slots are filled. A
    /// malformed or duplicate HELLO aborts the serve — the coordinator
    /// refuses to run a round over a broken fleet.
    fn accept_fleet(&self, n: usize, dim: usize, has_comp: bool) -> Result<NetTransport> {
        let mut slots: Vec<Option<Conn>> = Vec::new();
        slots.resize_with(n, || None);
        let mut joined = 0usize;
        let mut buf = Vec::new();
        while joined < n {
            let mut conn = Conn::new(self.listener.accept()?, self.timeout)?;
            let kind = read_frame(&mut conn.r, &mut buf).context("reading HELLO")?;
            ensure!(kind == KIND_HELLO, "first frame must be HELLO, got kind {kind}");
            let mut cur = Cur::new(&buf);
            let id = cur.u32()? as usize;
            let fleet = cur.u32()? as usize;
            let hdim = cur.u32()? as usize;
            cur.done()?;
            ensure!(fleet == n, "client expects a fleet of {fleet}, server runs {n}");
            ensure!(hdim == dim, "client expects dim {hdim}, server runs {dim}");
            ensure!(id < n, "client id {id} out of range for a fleet of {n}");
            ensure!(slots[id].is_none(), "client id {id} joined twice");
            slots[id] = Some(conn);
            joined += 1;
        }
        let conns: Vec<Conn> = slots.into_iter().map(|s| s.expect("all slots filled")).collect();
        Ok(NetTransport {
            conns: RefCell::new(conns),
            dim,
            has_comp,
            st: RefCell::new(NetState {
                input: PoolInput::default(),
                sup: Vec::new(),
                round: 0,
                layout: LAYOUT_SPARSE,
                frame: Vec::new(),
                body: Vec::new(),
                sv: SparseVec::default(),
            }),
        })
    }

    /// Drive a full networked run of `spec`: accept one connection per
    /// dataset client, stream every round over the sockets, broadcast
    /// DONE, and return the record — bit-for-bit the in-process fused
    /// run of the same spec. `on_eval` fires at every eval round (the
    /// JSON metrics line of `fedeff serve --listen`).
    pub fn serve(&self, spec: &Spec, on_eval: &mut dyn FnMut(&RoundStat)) -> Result<RunRecord> {
        ensure!(
            spec.scenario.is_none(),
            "time-aware scenarios are in-process only (the virtual clock replaces the real \
             barrier); drop [scenario] or serve without --listen"
        );
        let oracle = fleet_oracle(spec)?;
        let n = spec.dataset.clients;
        let d = oracle.dim();
        let mut alg = build_algorithm(&spec.algorithm, &oracle)?;
        let driver = build_driver(spec, n)?;
        let transport = self.accept_fleet(n, d, leaf_compressor(spec).is_some())?;
        let x0 = vec![0.5f32; d];
        let mut cb = |r: &RoundStat| on_eval(r);
        let rec = driver.run_with_transport(
            alg.as_mut(),
            &oracle,
            &transport,
            &x0,
            &spec_opts(spec),
            Some(&mut cb),
        )?;
        transport.shutdown()?;
        Ok(rec)
    }
}

// ---------------------------------------------------------------------
// client fleet
// ---------------------------------------------------------------------

/// Run the client side of a networked serve: one simulated client per
/// dataset client (each on its own thread with its own compressor
/// fork), all built from the same spec the server loaded, connecting to
/// `addr` and answering ROUND frames until DONE.
pub fn run_fleet(addr: &str, spec: &Spec) -> Result<()> {
    let oracle = fleet_oracle(spec)?;
    let n = spec.dataset.clients;
    let d = oracle.dim();
    let comp = leaf_compressor(spec);
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(n);
        for c in 0..n {
            let oracle = &oracle;
            let comp = comp.clone();
            handles.push(
                scope.spawn(move || client_loop(addr, c, n, d, comp.as_ref(), oracle)),
            );
        }
        let mut first_err = None;
        for (c, h) in handles.into_iter().enumerate() {
            let res = h.join().map_err(|_| anyhow::anyhow!("fleet client {c} panicked"));
            if let Err(e) = res.and_then(|r| r) {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })
}

/// One simulated client: HELLO, then execute every ROUND recipe through
/// the *same* fused pipeline the in-process workers run
/// ([`run_chunk`]), encode each channel's message with the wire codec,
/// and enforce the codec invariant (`bit_len == compressor-quoted
/// bits`) before sending.
fn client_loop(
    addr: &str,
    client: usize,
    fleet: usize,
    dim: usize,
    comp: Option<&(String, usize, usize)>,
    oracle: &RustLogReg,
) -> Result<()> {
    let stream = connect_retry(addr, Duration::from_secs(10))?;
    let mut conn = Conn::new(stream, DEFAULT_TIMEOUT)?;
    let mut hello = Vec::with_capacity(12);
    hello.extend_from_slice(&(client as u32).to_le_bytes());
    hello.extend_from_slice(&(fleet as u32).to_le_bytes());
    hello.extend_from_slice(&(dim as u32).to_le_bytes());
    write_frame(&mut conn.w, KIND_HELLO, &hello)?;
    conn.w.flush()?;

    let mut kit = FusedKit::default();
    let fork = match comp {
        Some((name, k, kp)) => Some(
            compressor_by_name(name, *k, *kp)?
                .fork()
                .with_context(|| format!("uplink compressor {name} has no sparse fork"))?,
        ),
        None => None,
    };
    let has_comp = fork.is_some();
    kit.install(fork);

    let mut input = PoolInput::default();
    input.cohort.push(client);
    input.scales.push(0.0);
    let mut out = WorkerOut::default();
    let mut frame = Vec::new();
    let mut msg = Vec::new();
    let mut w = BitWriter::new();
    let mut sv = SparseVec::default();

    loop {
        let kind = read_frame(&mut conn.r, &mut frame)
            .with_context(|| format!("client {client} reading from the coordinator"))?;
        match kind {
            KIND_DONE => return Ok(()),
            KIND_ROUND => {
                let layout = parse_round(&frame, dim, &mut input)?;
                let expect = if input.sup.is_empty() {
                    ensure!(has_comp, "unmasked round reached a compressor-less client");
                    LAYOUT_SPARSE
                } else if has_comp {
                    LAYOUT_MASKED_SPARSE
                } else {
                    LAYOUT_MASKED_RAW
                };
                ensure!(
                    layout == expect,
                    "coordinator negotiated layout {layout}, this client produces {expect}"
                );
                run_chunk(oracle, &input, &mut kit, &mut out, 0, 1, dim)?;
                let round32 = input.round as u32;
                let mut off = 0usize;
                for (ch, &len) in out.lens.iter().enumerate() {
                    let (lo, hi) = (off, off + len as usize);
                    off = hi;
                    sv.clear(dim);
                    for (&i, &v) in out.idx[lo..hi].iter().zip(&out.val[lo..hi]) {
                        sv.push(i, v);
                    }
                    w.clear();
                    match layout {
                        LAYOUT_SPARSE => codec::encode_sparse(&sv, &mut w)?,
                        LAYOUT_MASKED_RAW => codec::encode_masked_raw(&sv, &input.sup, &mut w)?,
                        LAYOUT_MASKED_SPARSE => {
                            codec::encode_masked_sparse(&sv, &input.sup, &mut w)?
                        }
                        _ => unreachable!("layout validated above"),
                    }
                    // the codec invariant, enforced on every live message
                    ensure!(
                        w.bit_len() == out.bits[ch],
                        "codec packed {} bits but the compressor quoted {} (client {client}, \
                         round {}, channel {ch})",
                        w.bit_len(),
                        out.bits[ch],
                        input.round
                    );
                    msg.clear();
                    msg.extend_from_slice(&round32.to_le_bytes());
                    msg.push(ch as u8);
                    msg.push(layout);
                    msg.extend_from_slice(&(sv.len() as u32).to_le_bytes());
                    msg.extend_from_slice(w.finish());
                    write_frame(&mut conn.w, KIND_MSG, &msg)?;
                }
                conn.w.flush()?;
            }
            other => bail!("unexpected frame kind {other} from the coordinator"),
        }
    }
}

/// Parse a ROUND frame into the client's single-slot [`PoolInput`];
/// returns the negotiated layout byte.
fn parse_round(frame: &[u8], dim: usize, input: &mut PoolInput) -> Result<u8> {
    let mut cur = Cur::new(frame);
    input.round = cur.u32()? as usize;
    input.seed = cur.u64()?;
    input.scales[0] = cur.f32()?;
    let layout = cur.u8()?;
    input.payload = match cur.u8()? {
        PAYLOAD_GRADIENT => FusedPayload::Gradient,
        PAYLOAD_LOCAL_SGD => {
            let steps = cur.u32()? as usize;
            let lr = cur.f32()?;
            let prox_mu = match cur.u8()? {
                0 => None,
                1 => Some(cur.f32()?),
                other => bail!("bad prox flag {other}"),
            };
            FusedPayload::LocalSgd { steps, lr, prox_mu }
        }
        other => bail!("unknown payload tag {other}"),
    };
    let d = cur.u32()? as usize;
    ensure!(d == dim, "round anchor dim {d} != client dim {dim}");
    input.point.clear();
    input.point.reserve(d);
    for _ in 0..d {
        input.point.push(cur.f32()?);
    }
    let nsup = cur.u32()? as usize;
    ensure!(nsup <= d, "support of {nsup} over dim {d}");
    input.sup.clear();
    input.sup.reserve(nsup);
    for _ in 0..nsup {
        input.sup.push(cur.u32()?);
    }
    ensure!(
        input.sup.windows(2).all(|p| p[0] < p[1]) && input.sup.iter().all(|&j| (j as usize) < d),
        "mask support must be strictly ascending within the model dimension"
    );
    cur.done()?;
    Ok(layout)
}
