//! The networked coordinator: real bytes between a socket fleet and the
//! fused O(k) merge, served by a readiness-driven event loop
//! (DESIGN.md §Wire).
//!
//! `fedeff serve --listen ADDR` binds a [`NetServer`] (TCP loopback or
//! a Unix domain socket; addresses are `tcp:HOST:PORT` / `uds:PATH`),
//! accepts one length-framed connection per dataset client, and drives
//! the same [`crate::coordinator::driver::Driver`] round loop as an
//! in-process run — with the client pipeline executing on the other end
//! of the sockets. A [`NetTransport`] implements the driver's
//! fused-uplink seam over a single-threaded [`super::evloop`] event
//! loop: every socket is non-blocking, each connection accumulates
//! bytes in a compacting receive window (partial-frame reassembly),
//! and complete MSG frames are decoded **on arrival** — whatever order
//! the kernel delivers them — straight into per-`(client, channel)`
//! staging slots (`StagedUplink`). Once the round is fully staged,
//! the slots are committed to the driver **in cohort order, channels
//! ascending**: the serial reference path's scatter sequence, which is
//! what keeps a networked run bit-for-bit identical to the in-process
//! fused run (losses, bits_up, bits_down, comm cost; pinned by
//! rust/tests/serve_net.rs and the serve-smoke CI job at 1024 clients).
//! Arrival order affects only *when* decode work happens; commit order
//! is fixed by the contract.
//!
//! The ROUND broadcast is encoded **once** per round; the only
//! per-client bytes are the 4 little-endian scale bytes, which travel
//! as the middle segment of a 3-segment vectored write around the
//! shared frame — the frame itself is never copied or patched per
//! client. Writes drain through the event loop with explicit
//! backpressure state (`Outgoing::sent`), so a client with a full
//! socket buffer delays only its own frames.
//!
//! Frame layout (little-endian): `u32 len | u8 kind | payload`, where
//! `len` counts the kind byte plus the payload and is capped at
//! [`MAX_FRAME`]. Kinds: HELLO (client joins: id, fleet size, dim),
//! ROUND (server→client round recipe), MSG (client→server one uplink
//! channel: round, channel, layout, pair count, bit-packed codec body,
//! zero-padded to bytes), DONE (server→fleet shutdown). Malformed,
//! truncated or oversized frames produce `anyhow` errors and a closed
//! connection — never a panic, and never a hang: every connection the
//! round is waiting on carries a progress deadline, refreshed on every
//! byte of socket progress, and a stalled client is evicted loudly (by
//! name) when *its own* deadline lapses while every other connection
//! keeps decoding.

use std::cell::RefCell;
use std::io::{self, BufReader, BufWriter, IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::bits::BitWriter;
use super::codec::{self, LAYOUT_MASKED_RAW, LAYOUT_MASKED_SPARSE, LAYOUT_SPARSE};
use super::evloop;
use crate::algorithms::build_algorithm;
use crate::algorithms::RunOptions;
use crate::compress::SparseVec;
use crate::config::{build_driver, compressor_by_name, Spec};
use crate::coordinator::fused::{run_chunk, FusedKit, FusedPayload, StagedUplink};
use crate::coordinator::{FusedUplink, PoolInput, WorkerOut};
use crate::data::synth::Heterogeneity;
use crate::metrics::{RoundStat, RunRecord};
use crate::oracle::logreg_rs::RustLogReg;
use crate::oracle::Oracle;

/// Hard ceiling on one frame's size (kind byte + payload): 64 MiB.
pub const MAX_FRAME: u32 = 1 << 26;
/// Userspace buffer per client-side connection half, and the server's
/// per-`read` chunk (the bounded backpressure window; everything beyond
/// it waits in the kernel socket buffer).
const CONN_BUF: usize = 64 * 1024;
/// Consumed-prefix size at which a receive window compacts (memmoves
/// its live tail to the front).
const COMPACT_AT: usize = 64 * 1024;
/// Default progress deadline — a peer that stops mid-frame errors out
/// instead of hanging the round loop.
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(30);

const KIND_HELLO: u8 = 1;
const KIND_ROUND: u8 = 2;
const KIND_MSG: u8 = 3;
const KIND_DONE: u8 = 4;

/// The complete DONE frame: `len=1 | kind` and no payload.
const DONE_FRAME: [u8; 5] = [1, 0, 0, 0, KIND_DONE];

const PAYLOAD_GRADIENT: u8 = 0;
const PAYLOAD_LOCAL_SGD: u8 = 1;

// ---------------------------------------------------------------------
// address grammar + stream/listener abstraction
// ---------------------------------------------------------------------

/// One connected byte stream (TCP or, on Unix, a domain socket).
pub enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
            #[cfg(unix)]
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
        })
    }

    fn set_read_timeout(&self, t: Duration) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(Some(t))?,
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(Some(t))?,
        }
        Ok(())
    }

    fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nb)?,
            #[cfg(unix)]
            Stream::Unix(s) => s.set_nonblocking(nb)?,
        }
        Ok(())
    }

    /// Disable Nagle on TCP (frame latency beats batching here); a
    /// no-op for domain sockets.
    fn set_nodelay(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.set_nodelay(true);
            }
            #[cfg(unix)]
            Stream::Unix(_) => {}
        }
    }

    #[cfg(unix)]
    fn raw_fd(&self) -> evloop::RawFd {
        use std::os::unix::io::AsRawFd;
        match self {
            Stream::Tcp(s) => s.as_raw_fd(),
            Stream::Unix(s) => s.as_raw_fd(),
        }
    }

    #[cfg(not(unix))]
    fn raw_fd(&self) -> evloop::RawFd {
        0
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn write_vectored(&mut self, bufs: &[IoSlice<'_>]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write_vectored(bufs),
            #[cfg(unix)]
            Stream::Unix(s) => s.write_vectored(bufs),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// A bound accept socket. `tcp:HOST:PORT` binds TCP with `SO_REUSEADDR`
/// (port 0 picks a free port — read the real one back from
/// [`Listener::local_addr`]); `uds:PATH` binds a Unix domain socket
/// (stale socket files are replaced, and the path is unlinked again
/// when the listener drops).
pub enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

impl Listener {
    pub fn bind(addr: &str) -> Result<Listener> {
        if let Some(hostport) = addr.strip_prefix("tcp:") {
            let l = evloop::bind_tcp_reuseaddr(hostport)
                .with_context(|| format!("binding tcp listener on {hostport}"))?;
            return Ok(Listener::Tcp(l));
        }
        if let Some(path) = addr.strip_prefix("uds:") {
            #[cfg(unix)]
            {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .with_context(|| format!("binding unix socket {path}"))?;
                return Ok(Listener::Unix(l, PathBuf::from(path)));
            }
            #[cfg(not(unix))]
            bail!("uds: addresses need a Unix platform; use tcp:HOST:PORT");
        }
        bail!("address {addr:?} is neither tcp:HOST:PORT nor uds:PATH")
    }

    /// The canonical address peers connect to (resolves `tcp:...:0` to
    /// the picked port).
    pub fn local_addr(&self) -> Result<String> {
        Ok(match self {
            Listener::Tcp(l) => format!("tcp:{}", l.local_addr()?),
            #[cfg(unix)]
            Listener::Unix(_, path) => format!("uds:{}", path.display()),
        })
    }

    fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb)?,
            #[cfg(unix)]
            Listener::Unix(l, _) => l.set_nonblocking(nb)?,
        }
        Ok(())
    }

    /// Accept one connection if the queue is non-empty. Transient
    /// accept failures (`WouldBlock`, `EINTR`, a peer that aborted
    /// between readiness and accept) report "nothing to accept" — the
    /// next readiness lap retries.
    fn accept_nonblocking(&self) -> Result<Option<Stream>> {
        let r = match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l, _) => l.accept().map(|(s, _)| Stream::Unix(s)),
        };
        match r {
            Ok(s) => Ok(Some(s)),
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::Interrupted
                        | io::ErrorKind::ConnectionAborted
                ) =>
            {
                Ok(None)
            }
            Err(e) => Err(e.into()),
        }
    }

    #[cfg(unix)]
    fn raw_fd(&self) -> evloop::RawFd {
        use std::os::unix::io::AsRawFd;
        match self {
            Listener::Tcp(l) => l.as_raw_fd(),
            Listener::Unix(l, _) => l.as_raw_fd(),
        }
    }

    #[cfg(not(unix))]
    fn raw_fd(&self) -> evloop::RawFd {
        0
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        // socket-lifecycle hygiene: a dead server must not leave a
        // stale socket file for the next bind to trip over
        #[cfg(unix)]
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Connect to a `tcp:`/`uds:` address.
pub fn connect(addr: &str) -> Result<Stream> {
    if let Some(hostport) = addr.strip_prefix("tcp:") {
        return Ok(Stream::Tcp(
            TcpStream::connect(hostport).with_context(|| format!("connecting to {hostport}"))?,
        ));
    }
    if let Some(path) = addr.strip_prefix("uds:") {
        #[cfg(unix)]
        return Ok(Stream::Unix(
            UnixStream::connect(path).with_context(|| format!("connecting to {path}"))?,
        ));
        #[cfg(not(unix))]
        bail!("uds: addresses need a Unix platform; use tcp:HOST:PORT");
    }
    bail!("address {addr:?} is neither tcp:HOST:PORT nor uds:PATH")
}

/// [`connect`] with retries while the server is still binding/accepting
/// (the fleet usually races the coordinator's startup).
fn connect_retry(addr: &str, budget: Duration) -> Result<Stream> {
    let t0 = Instant::now();
    loop {
        match connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if t0.elapsed() < budget => {
                let _ = e;
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => return Err(e),
        }
    }
}

// ---------------------------------------------------------------------
// frames
// ---------------------------------------------------------------------

/// One blocking client-side connection: buffered reader/writer halves
/// over cloned handles. (The server side is non-blocking and uses
/// [`RecvBuf`] instead.)
struct Conn {
    r: BufReader<Stream>,
    w: BufWriter<Stream>,
}

impl Conn {
    fn new(s: Stream, timeout: Duration) -> Result<Conn> {
        s.set_read_timeout(timeout)?;
        let rh = s.try_clone()?;
        Ok(Conn {
            r: BufReader::with_capacity(CONN_BUF, rh),
            w: BufWriter::with_capacity(CONN_BUF, s),
        })
    }
}

fn write_frame(w: &mut impl Write, kind: u8, payload: &[u8]) -> Result<()> {
    let len = payload.len() as u64 + 1;
    ensure!(len <= MAX_FRAME as u64, "frame of {len} bytes exceeds MAX_FRAME ({MAX_FRAME})");
    w.write_all(&(len as u32).to_le_bytes())?;
    w.write_all(&[kind])?;
    w.write_all(payload)?;
    Ok(())
}

/// Read one frame into `buf` (payload only); returns the kind byte.
/// Zero-length and oversized frames are protocol errors.
fn read_frame(r: &mut impl Read, buf: &mut Vec<u8>) -> Result<u8> {
    let mut hdr = [0u8; 4];
    r.read_exact(&mut hdr).context("reading frame header")?;
    let len = u32::from_le_bytes(hdr);
    ensure!(len >= 1, "zero-length frame");
    ensure!(len <= MAX_FRAME, "oversized frame: {len} bytes (max {MAX_FRAME})");
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind).context("reading frame kind")?;
    buf.clear();
    buf.resize(len as usize - 1, 0);
    r.read_exact(buf).context("reading frame payload")?;
    Ok(kind[0])
}

/// Inspect the head of a receive window for one complete frame without
/// consuming it: `Ok(Some((kind, total_len)))` when `data[..total_len]`
/// is a whole frame (payload at `data[5..total_len]`), `Ok(None)` when
/// more bytes must arrive, and an error for frames that can never
/// become valid (zero-length, oversized) — checked from the 4 header
/// bytes alone, before any buffering commitment.
fn peek_frame(data: &[u8]) -> Result<Option<(u8, usize)>> {
    if data.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(data[..4].try_into().expect("4 bytes"));
    ensure!(len >= 1, "zero-length frame");
    ensure!(len <= MAX_FRAME, "oversized frame: {len} bytes (max {MAX_FRAME})");
    let total = 4 + len as usize;
    if data.len() < total {
        return Ok(None);
    }
    Ok(Some((data[4], total)))
}

/// Bounds-checked little-endian cursor over a frame payload.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).context("frame length overflow")?;
        ensure!(
            end <= self.buf.len(),
            "frame truncated: wanted {n} bytes past offset {}",
            self.pos
        );
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn rest(self) -> &'a [u8] {
        &self.buf[self.pos..]
    }

    fn done(&self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "{} trailing bytes in frame",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------
// shared spec plumbing (the config path `run`, `serve` and the fleet
// all resolve identically)
// ---------------------------------------------------------------------

/// Build the pure-Rust logreg oracle a spec describes — the exact
/// dataset construction `fedeff run` uses (profile, clients,
/// heterogeneity, regularizer, seed), so server, fleet and in-process
/// comparisons all train on identical data.
pub fn fleet_oracle(spec: &Spec) -> Result<RustLogReg> {
    let ds = &spec.dataset;
    ensure!(ds.kind == "logreg", "networked serving drives the logreg substrate, not {}", ds.kind);
    let het = match ds.heterogeneity.as_deref() {
        Some("iid") => Heterogeneity::Iid,
        Some("class") => Heterogeneity::ClassSkew(0.85),
        _ => Heterogeneity::FeatureShift(0.5),
    };
    let (d, m) = crate::data::synth::logreg_profile(&ds.profile)
        .ok_or_else(|| anyhow::anyhow!("unknown logreg profile {}", ds.profile))?;
    let mut rng = crate::rng(spec.experiment.seed);
    let data = crate::data::synth::logreg_dataset(d, m, ds.clients, het, 0.3, &mut rng);
    Ok(RustLogReg::new(data, ds.reg))
}

/// The effective leaf (client-out) uplink compressor of a spec —
/// mirrors the driver's resolution (a `[links.up.l0]` edge under an
/// executed tree overrides the flat `[compressor] up`).
pub fn leaf_compressor(spec: &Spec) -> Option<(String, usize, usize)> {
    if spec.topology.as_ref().is_some_and(|t| t.levels.is_some()) {
        if let Some(Some(e)) = spec.links.up_edges.first() {
            return Some((e.kind.clone(), e.k, e.k_prime));
        }
    }
    spec.links.up.as_ref().map(|u| (u.clone(), spec.links.k, spec.links.k_prime))
}

/// [`RunOptions`] a spec describes (the serve path's view).
fn spec_opts(spec: &Spec) -> RunOptions {
    RunOptions {
        rounds: spec.experiment.rounds,
        eval_every: spec.experiment.eval_every,
        seed: spec.experiment.seed,
        ..Default::default()
    }
}

/// Run a spec in-process on the fused worker-pool path, streaming eval
/// rounds — the reference a networked run must match bit for bit.
pub fn run_in_process(spec: &Spec, on_eval: &mut dyn FnMut(&RoundStat)) -> Result<RunRecord> {
    let oracle = fleet_oracle(spec)?;
    let d = oracle.dim();
    let mut alg = build_algorithm(&spec.algorithm, &oracle)?;
    let driver = build_driver(spec, spec.dataset.clients)?;
    let x0 = vec![0.5f32; d];
    driver.run_parallel_streaming(alg.as_mut(), &oracle, &x0, &spec_opts(spec), |r| on_eval(r))
}

// ---------------------------------------------------------------------
// server: event loop over non-blocking connections
// ---------------------------------------------------------------------

/// Per-connection receive window: bytes land at the tail, complete
/// frames are consumed from the head, and a partial frame simply stays
/// buffered until its remaining bytes arrive (reassembly across any
/// number of reads — a peer may trickle one byte at a time). The
/// consumed prefix slides forward without copying until it outgrows
/// [`COMPACT_AT`], then the live tail is compacted to the front; frame
/// payloads are decoded by *borrowing* straight out of this buffer, so
/// the steady-state round loop does no per-frame allocation at all.
#[derive(Default)]
struct RecvBuf {
    buf: Vec<u8>,
    start: usize,
}

impl RecvBuf {
    fn data(&self) -> &[u8] {
        &self.buf[self.start..]
    }

    fn is_empty(&self) -> bool {
        self.start == self.buf.len()
    }

    fn consume(&mut self, n: usize) {
        self.start += n;
        debug_assert!(self.start <= self.buf.len());
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        } else if self.start >= COMPACT_AT {
            self.buf.drain(..self.start);
            self.start = 0;
        }
    }

    /// One non-blocking `read` of up to [`CONN_BUF`] bytes into the
    /// tail; returns the byte count (0 = EOF) or the raw I/O error.
    fn fill(&mut self, stream: &mut Stream) -> io::Result<usize> {
        let len = self.buf.len();
        self.buf.resize(len + CONN_BUF, 0);
        match stream.read(&mut self.buf[len..]) {
            Ok(n) => {
                self.buf.truncate(len + n);
                Ok(n)
            }
            Err(e) => {
                self.buf.truncate(len);
                Err(e)
            }
        }
    }
}

/// A broadcast frame draining through the event loop; `sent` is the
/// write-backpressure cursor (bytes already accepted by the kernel).
enum Outgoing {
    Round { sent: usize },
    Done { sent: usize },
}

/// One accepted (post-HELLO) connection in the event loop.
struct EvConn {
    stream: Stream,
    rbuf: RecvBuf,
    /// This client's 4 little-endian scale bytes — the middle segment
    /// of its vectored ROUND write, in place of the shared frame's
    /// zeroed hole.
    scale: [u8; 4],
    out: Option<Outgoing>,
    /// Progress deadline: refreshed on every byte read or written.
    /// Consulted only while the round is actually waiting on this
    /// connection.
    deadline: Instant,
    /// False once EOF or a hard I/O error was observed.
    open: bool,
}

/// Live serve counters, readable via [`NetServer::stats`] (the
/// `--metrics` JSON line and the adversarial tests' progress probes).
#[derive(Clone, Default)]
pub struct ServeStats {
    /// Bytes read off client sockets (frames and fragments alike).
    pub bytes_in: u64,
    /// Bytes written to client sockets (ROUND broadcasts + DONE).
    pub bytes_out: u64,
    /// MSG frames decoded and staged.
    pub frames_in: u64,
    /// ROUND frames enqueued (rounds × cohort size).
    pub rounds_broadcast: u64,
    /// Connections that completed HELLO and are still open.
    pub connected: usize,
    /// Pre-HELLO connections evicted on their idle deadline.
    pub evicted: u64,
    /// Pre-HELLO connections that hung up on their own (churn).
    pub churned: u64,
    /// Connections shed: beyond `--max-clients`, or arriving after the
    /// fleet was already complete.
    pub rejected: u64,
}

/// What one [`pump`] call runs the event loop for.
#[derive(Clone, Copy, PartialEq)]
enum Until {
    /// One zero-timeout lap: start whatever I/O is ready, never block.
    Opportunistic,
    /// Every queued broadcast frame fully written.
    WritesFlushed,
    /// The dispatched round fully staged (writes drain on the way).
    StagingComplete,
}

/// Copyable slice of the round context MSG validation echoes against.
#[derive(Clone, Copy)]
struct RoundMeta {
    round: usize,
    layout: u8,
}

/// Mutable event-loop state behind [`NetTransport`]'s interior
/// mutability (the driver's fused seam takes `&self`).
struct TransportInner {
    conns: Vec<EvConn>,
    staging: StagedUplink,
    poller: evloop::Poller,
    /// Poll-slot → connection-id map, rebuilt each lap (slot 0 is the
    /// listener).
    pslots: Vec<usize>,
    /// The round's shared ROUND frame (header + body), encoded once;
    /// per-client writes splice each connection's scale bytes over the
    /// hole at `scale_off`.
    round_frame: Vec<u8>,
    scale_off: usize,
    round: usize,
    layout: u8,
    sup: Vec<u32>,
    input: PoolInput,
}

/// The driver-facing side of an accepted fleet: implements the fused
/// uplink seam over the event loop — arrival-order decode into
/// `StagedUplink`, cohort-order commit.
pub struct NetTransport<'a> {
    srv: &'a NetServer,
    dim: usize,
    has_comp: bool,
    inner: RefCell<TransportInner>,
}

impl NetTransport<'_> {
    /// Broadcast DONE to every open connection and drain — the fleet's
    /// clean-shutdown signal.
    pub fn shutdown(&self) -> Result<()> {
        let mut guard = self.inner.borrow_mut();
        let inner = &mut *guard;
        let now = Instant::now();
        for c in inner.conns.iter_mut() {
            if c.open {
                c.out = Some(Outgoing::Done { sent: 0 });
                c.deadline = now + self.srv.timeout;
            }
        }
        pump(self.srv, inner, self.dim, Until::WritesFlushed).context("broadcasting DONE")
    }
}

impl FusedUplink for NetTransport<'_> {
    fn fused_dispatch(
        &self,
        cohort: &[usize],
        _groups: Option<&[usize]>,
        channels: usize,
        fill: &mut dyn FnMut(&mut PoolInput),
    ) -> Result<()> {
        let mut guard = self.inner.borrow_mut();
        let inner = &mut *guard;
        let n = inner.conns.len();
        inner.input.cohort.clear();
        inner.input.cohort.extend_from_slice(cohort);
        fill(&mut inner.input);
        let inp = &inner.input;
        ensure!(inp.point.len() == self.dim, "round anchor has the wrong dimension");
        ensure!(inp.scales.len() == cohort.len(), "round scales do not cover the cohort");
        let layout = if inp.sup.is_empty() {
            ensure!(self.has_comp, "an unmasked networked round needs an uplink compressor");
            LAYOUT_SPARSE
        } else if self.has_comp {
            LAYOUT_MASKED_SPARSE
        } else {
            LAYOUT_MASKED_RAW
        };
        inner.layout = layout;
        inner.round = inp.round;
        inner.sup.clear();
        inner.sup.extend_from_slice(&inp.sup);
        inner.staging.begin_round(cohort, channels, n);

        // one shared ROUND frame per round — encoded once, never
        // re-patched per client; the scale hole stays zeroed and each
        // connection's 4 scale bytes are spliced in by the vectored
        // write
        let f = &mut inner.round_frame;
        f.clear();
        f.extend_from_slice(&[0u8; 4]); // length, patched below
        f.push(KIND_ROUND);
        f.extend_from_slice(&u32::try_from(inp.round).context("round exceeds u32")?.to_le_bytes());
        f.extend_from_slice(&inp.seed.to_le_bytes());
        let scale_off = f.len();
        f.extend_from_slice(&0f32.to_le_bytes());
        f.push(layout);
        match inp.payload {
            FusedPayload::Gradient => f.push(PAYLOAD_GRADIENT),
            FusedPayload::LocalSgd { steps, lr, prox_mu } => {
                f.push(PAYLOAD_LOCAL_SGD);
                f.extend_from_slice(
                    &u32::try_from(steps).context("local steps exceed u32")?.to_le_bytes(),
                );
                f.extend_from_slice(&lr.to_le_bytes());
                match prox_mu {
                    Some(mu) => {
                        f.push(1);
                        f.extend_from_slice(&mu.to_le_bytes());
                    }
                    None => f.push(0),
                }
            }
            FusedPayload::Scaffold { .. } => bail!(
                "stateful (Scaffold) payloads cannot be served over the wire: the control \
                 rows live in server memory"
            ),
            FusedPayload::None => bail!("networked round dispatched without a payload recipe"),
        }
        f.extend_from_slice(&(self.dim as u32).to_le_bytes());
        for &v in &inp.point {
            f.extend_from_slice(&v.to_le_bytes());
        }
        f.extend_from_slice(&(inp.sup.len() as u32).to_le_bytes());
        for &j in &inp.sup {
            f.extend_from_slice(&j.to_le_bytes());
        }
        let len = f.len() as u64 - 4;
        ensure!(len <= MAX_FRAME as u64, "ROUND frame of {len} bytes exceeds MAX_FRAME");
        let len32 = (len as u32).to_le_bytes();
        f[..4].copy_from_slice(&len32);
        inner.scale_off = scale_off;
        // broadcast-cost invariant: scale patching never changes the
        // frame, so every client receives the same anchor payload the
        // ledger prices — 32·d bits, `dense_bits(d)`, the unmasked
        // uncompressed downlink charge
        let anchor_bits = 32 * inp.point.len() as u64;
        ensure!(
            anchor_bits == crate::algorithms::dense_bits(inp.point.len()),
            "ROUND anchor packs {anchor_bits} bits but the ledger books {}",
            crate::algorithms::dense_bits(inp.point.len())
        );

        let now = Instant::now();
        for (p, &client) in cohort.iter().enumerate() {
            let c = inner
                .conns
                .get_mut(client)
                .with_context(|| format!("cohort client {client} has no connection"))?;
            ensure!(
                c.open,
                "cohort client {client} disconnected in an earlier round; cannot dispatch \
                 round {}",
                inp.round
            );
            c.scale = inp.scales[p].to_le_bytes();
            c.out = Some(Outgoing::Round { sent: 0 });
            c.deadline = now + self.srv.timeout;
        }
        self.srv.stat(|s| s.rounds_broadcast += cohort.len() as u64);

        // adversarially early bytes (a peer answering before its ROUND
        // even went out) may already sit in a receive window; surface
        // them now so they fail loudly instead of idling untouched
        {
            let TransportInner { conns, staging, sup, round, layout, .. } = &mut *inner;
            let meta = RoundMeta { round: *round, layout: *layout };
            for (id, c) in conns.iter_mut().enumerate() {
                if c.open && !c.rbuf.is_empty() {
                    parse_msg_frames(self.srv, c, id, staging, meta, sup, self.dim)?;
                }
            }
        }
        // start the broadcast on whatever sockets are ready right now;
        // the rest drains during the visit-phase event loop
        pump(self.srv, inner, self.dim, Until::Opportunistic)
    }

    fn fused_visit(
        &self,
        cohort: &[usize],
        channels: usize,
        visit: &mut dyn FnMut(usize, usize, &[u32], &[f32], u64) -> Result<()>,
    ) -> Result<()> {
        let mut guard = self.inner.borrow_mut();
        let inner = &mut *guard;
        ensure!(
            channels == inner.staging.channels(),
            "visit expects {channels} channels but the dispatch staged {}",
            inner.staging.channels()
        );
        pump(self.srv, inner, self.dim, Until::StagingComplete)?;
        inner.staging.commit(cohort, visit)
    }
}

/// One call into the event loop: poll readiness over the listener and
/// every open connection, then accept/read/decode/write whatever is
/// ready, looping until the `until` condition holds. Deadlines are
/// enforced *per connection* and only for connections the condition is
/// actually waiting on — a stalled client is named and evicted when its
/// own deadline lapses, while every other connection keeps reading,
/// decoding and staging in the meantime.
fn pump(srv: &NetServer, inner: &mut TransportInner, dim: usize, until: Until) -> Result<()> {
    let TransportInner {
        conns,
        staging,
        poller,
        pslots,
        round_frame,
        scale_off,
        round,
        layout,
        sup,
        ..
    } = inner;
    let meta = RoundMeta { round: *round, layout: *layout };
    let scale_off = *scale_off;
    loop {
        let writes_pending = conns.iter().any(|c| c.open && c.out.is_some());
        let done = match until {
            Until::Opportunistic => false,
            Until::WritesFlushed => !writes_pending,
            Until::StagingComplete => !writes_pending && staging.is_complete(),
        };
        if done {
            return Ok(());
        }

        // deadline sweep over the connections this call waits on
        let now = Instant::now();
        let mut next_deadline: Option<Instant> = None;
        for (id, c) in conns.iter().enumerate() {
            if !c.open {
                continue;
            }
            let awaited = c.out.is_some()
                || (until == Until::StagingComplete
                    && staging.cohort_pos(id).is_some_and(|p| !staging.client_complete(p)));
            if !awaited {
                continue;
            }
            if now >= c.deadline {
                bail!(
                    "client {id} stalled: no socket progress within {:?} (round {}); evicting \
                     it and aborting the round — all other connections kept their own deadlines",
                    srv.timeout,
                    meta.round
                );
            }
            next_deadline = Some(next_deadline.map_or(c.deadline, |d| d.min(c.deadline)));
        }

        poller.clear();
        pslots.clear();
        poller.push(srv.listener.raw_fd(), evloop::Interest { read: true, write: false });
        pslots.push(usize::MAX);
        for (id, c) in conns.iter().enumerate() {
            if !c.open {
                continue;
            }
            let interest = evloop::Interest { read: true, write: c.out.is_some() };
            poller.push(c.stream.raw_fd(), interest);
            pslots.push(id);
        }
        let timeout = match until {
            Until::Opportunistic => Duration::ZERO,
            _ => next_deadline
                .map_or(Duration::from_millis(100), |d| d.saturating_duration_since(now)),
        };
        poller.wait(timeout)?;

        for (slot, &id) in pslots.iter().enumerate() {
            let rd = poller.readiness(slot);
            if !(rd.readable || rd.writable || rd.closed) {
                continue;
            }
            if id == usize::MAX {
                // the fleet is complete: late connections are churn,
                // shed without touching the round
                while let Some(s) = srv.listener.accept_nonblocking()? {
                    drop(s);
                    srv.stat(|st| st.rejected += 1);
                }
                continue;
            }
            let c = &mut conns[id];
            if c.out.is_some() && (rd.writable || rd.closed) {
                drain_conn_out(srv, c, id, round_frame, scale_off)?;
            }
            if rd.readable || rd.closed {
                loop {
                    match c.rbuf.fill(&mut c.stream) {
                        Ok(0) => {
                            c.open = false;
                            srv.stat(|st| st.connected = st.connected.saturating_sub(1));
                            break;
                        }
                        Ok(n) => {
                            c.deadline = Instant::now() + srv.timeout;
                            srv.stat(|st| st.bytes_in += n as u64);
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                        Err(e) => {
                            let _ = e;
                            c.open = false;
                            srv.stat(|st| st.connected = st.connected.saturating_sub(1));
                            break;
                        }
                    }
                }
                parse_msg_frames(srv, c, id, staging, meta, sup, dim)?;
                if !c.open {
                    let awaited = c.out.is_some()
                        || staging.cohort_pos(id).is_some_and(|p| !staging.client_complete(p));
                    ensure!(
                        !awaited,
                        "client {id} disconnected mid-round (round {}) with its work \
                         outstanding; the server keeps serving the remaining connections",
                        meta.round
                    );
                }
            }
        }
        if until == Until::Opportunistic {
            return Ok(());
        }
    }
}

/// Drain a connection's queued broadcast frame as far as the kernel
/// will take it right now. A ROUND goes out as a 3-segment vectored
/// write — shared frame before the scale hole, this client's 4 scale
/// bytes, shared frame after — so per-client cost is 4 bytes of state,
/// not a frame copy.
fn drain_conn_out(
    srv: &NetServer,
    c: &mut EvConn,
    id: usize,
    round_frame: &[u8],
    scale_off: usize,
) -> Result<()> {
    let EvConn { stream, scale, out, deadline, open, .. } = c;
    let round_parts: [&[u8]; 3] =
        [&round_frame[..scale_off], &scale[..], &round_frame[scale_off + 4..]];
    let done_parts: [&[u8]; 1] = [&DONE_FRAME];
    debug_assert_eq!(
        round_parts.iter().map(|p| p.len()).sum::<usize>(),
        round_frame.len(),
        "scale splice must preserve the frame length"
    );
    loop {
        let (is_round, sent_now) = match &*out {
            None => return Ok(()),
            Some(Outgoing::Round { sent }) => (true, *sent),
            Some(Outgoing::Done { sent }) => (false, *sent),
        };
        let parts: &[&[u8]] = if is_round { &round_parts } else { &done_parts };
        let total: usize = parts.iter().map(|p| p.len()).sum();
        let mut iov = [IoSlice::new(&[]); 3];
        let mut niov = 0usize;
        let mut off = sent_now;
        for p in parts {
            if off >= p.len() {
                off -= p.len();
                continue;
            }
            iov[niov] = IoSlice::new(&p[off..]);
            niov += 1;
            off = 0;
        }
        let wrote = match stream.write_vectored(&iov[..niov]) {
            Ok(0) => {
                *open = false;
                bail!("client {id} closed its socket mid-broadcast");
            }
            Ok(n) => n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(()),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                *open = false;
                bail!("client {id} broadcast write failed: {e}");
            }
        };
        srv.stat(|st| st.bytes_out += wrote as u64);
        *deadline = Instant::now() + srv.timeout;
        let new_sent = sent_now + wrote;
        *out = if new_sent >= total {
            None
        } else if is_round {
            Some(Outgoing::Round { sent: new_sent })
        } else {
            Some(Outgoing::Done { sent: new_sent })
        };
    }
}

/// Decode every complete MSG frame buffered on one connection into its
/// staging slot — the arrival-order half of the deterministic merge.
/// The bit-packed body is borrowed straight out of the receive window
/// (no per-frame copy) and validated against the round context: round
/// echo, channel range, negotiated layout, and the exact byte length
/// the server-side bit formula dictates.
fn parse_msg_frames(
    srv: &NetServer,
    c: &mut EvConn,
    id: usize,
    staging: &mut StagedUplink,
    meta: RoundMeta,
    sup: &[u32],
    dim: usize,
) -> Result<()> {
    loop {
        let flen = {
            let data = c.rbuf.data();
            let Some((kind, flen)) = peek_frame(data)? else { return Ok(()) };
            ensure!(kind == KIND_MSG, "client {id} sent frame kind {kind}, expected MSG");
            let payload = &data[5..flen];
            let mut cur = Cur::new(payload);
            let mround = cur.u32()? as usize;
            let mch = cur.u8()? as usize;
            let mlayout = cur.u8()?;
            let k = cur.u32()? as usize;
            let body = cur.rest();
            let pos = staging
                .cohort_pos(id)
                .with_context(|| format!("client {id} sent an MSG outside its cohort round"))?;
            ensure!(
                mround == meta.round && mch < staging.channels() && mlayout == meta.layout,
                "client {id} answered (round {mround}, ch {mch}, layout {mlayout}); expected \
                 (round {}, {} channels, layout {})",
                meta.round,
                staging.channels(),
                meta.layout
            );
            staging
                .stage_with(pos, mch, &mut |sv| {
                    codec::decode_wire_body(mlayout, k, body, dim, sup, sv)
                })
                .with_context(|| format!("decoding client {id} channel {mch}"))?;
            flen
        };
        c.rbuf.consume(flen);
        srv.stat(|st| st.frames_in += 1);
    }
}

/// A pre-HELLO connection: accepted, polled, not yet part of the fleet.
struct Pending {
    stream: Stream,
    rbuf: RecvBuf,
    deadline: Instant,
}

/// What one readiness lap decided about a pending connection.
enum HelloStep {
    /// Frame still incomplete; keep waiting.
    Wait,
    /// Peer hung up before completing HELLO; quiet churn drop.
    Dead,
    /// Valid HELLO: join the fleet as `id`, consuming `flen` bytes
    /// (any extra buffered bytes ride along into the event loop).
    Join { id: usize, flen: usize },
}

/// A bound coordinator endpoint. [`NetServer::bind`] first (so tests
/// and scripts can read the real port before starting a fleet), then
/// [`NetServer::serve`] a spec against it.
pub struct NetServer {
    listener: Listener,
    /// Per-connection progress deadline (reads, writes, and the
    /// pre-HELLO idle eviction all refresh against it).
    pub timeout: Duration,
    /// Cap on concurrently tracked connections; extras are accepted
    /// and immediately shed. `None` = uncapped.
    pub max_clients: Option<usize>,
    stats: RefCell<ServeStats>,
}

impl NetServer {
    pub fn bind(addr: &str) -> Result<NetServer> {
        Ok(NetServer {
            listener: Listener::bind(addr)?,
            timeout: DEFAULT_TIMEOUT,
            max_clients: None,
            stats: RefCell::new(ServeStats::default()),
        })
    }

    /// The canonical connect address (resolves `tcp:...:0`).
    pub fn local_addr(&self) -> Result<String> {
        self.listener.local_addr()
    }

    /// Snapshot of the live serve counters.
    pub fn stats(&self) -> ServeStats {
        self.stats.borrow().clone()
    }

    fn stat(&self, f: impl FnOnce(&mut ServeStats)) {
        f(&mut self.stats.borrow_mut());
    }

    /// Accept HELLO handshakes until all `n` client slots are filled,
    /// multiplexing every pending connection: a peer may trickle its
    /// HELLO byte by byte, a silent peer is evicted on its own idle
    /// deadline without delaying anyone, and a malformed or duplicate
    /// HELLO aborts the serve — the coordinator refuses to run a round
    /// over a broken fleet. The whole accept phase also carries a
    /// global no-progress deadline so a fleet that never completes
    /// errors out instead of hanging.
    fn accept_fleet(&self, n: usize, dim: usize, has_comp: bool) -> Result<NetTransport<'_>> {
        let cap = self.max_clients.unwrap_or(usize::MAX);
        ensure!(cap >= n, "--max-clients {cap} cannot host a fleet of {n}");
        self.listener.set_nonblocking(true)?;
        let mut slots: Vec<Option<(Stream, RecvBuf)>> = Vec::new();
        slots.resize_with(n, || None);
        let mut pending: Vec<Option<Pending>> = Vec::new();
        let mut poller = evloop::Poller::new();
        let mut joined = 0usize;
        let mut last_progress = Instant::now();
        while joined < n {
            let now = Instant::now();
            ensure!(
                now < last_progress + self.timeout,
                "timed out waiting for the fleet: {joined}/{n} clients joined within {:?}",
                self.timeout
            );
            // evict pre-HELLO connections that sat silent past their
            // own deadline — they never delay the fleet
            for p in pending.iter_mut() {
                if p.as_ref().is_some_and(|q| now >= q.deadline) {
                    *p = None;
                    self.stat(|s| s.evicted += 1);
                }
            }
            pending.retain(|p| p.is_some());

            poller.clear();
            poller.push(self.listener.raw_fd(), evloop::Interest { read: true, write: false });
            let mut wake = last_progress + self.timeout;
            for p in pending.iter().flatten() {
                poller.push(p.stream.raw_fd(), evloop::Interest { read: true, write: false });
                wake = wake.min(p.deadline);
            }
            let registered = pending.len();
            poller.wait(wake.saturating_duration_since(now))?;

            if poller.readiness(0).readable {
                while let Some(s) = self.listener.accept_nonblocking()? {
                    if joined + pending.len() >= cap {
                        drop(s);
                        self.stat(|st| st.rejected += 1);
                        continue;
                    }
                    s.set_nonblocking(true)?;
                    s.set_nodelay();
                    pending.push(Some(Pending {
                        stream: s,
                        rbuf: RecvBuf::default(),
                        deadline: Instant::now() + self.timeout,
                    }));
                }
            }

            for i in 0..registered {
                let rd = poller.readiness(1 + i);
                if !(rd.readable || rd.closed) {
                    continue;
                }
                let step = {
                    let Some(p) = pending[i].as_mut() else { continue };
                    let mut open = true;
                    loop {
                        match p.rbuf.fill(&mut p.stream) {
                            Ok(0) => {
                                open = false;
                                break;
                            }
                            Ok(nb) => {
                                p.deadline = Instant::now() + self.timeout;
                                self.stat(|st| st.bytes_in += nb as u64);
                            }
                            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                            Err(e) => {
                                let _ = e;
                                open = false;
                                break;
                            }
                        }
                    }
                    match peek_frame(p.rbuf.data()).context("reading HELLO")? {
                        Some((kind, flen)) => {
                            ensure!(
                                kind == KIND_HELLO,
                                "first frame must be HELLO, got kind {kind}"
                            );
                            let mut cur = Cur::new(&p.rbuf.data()[5..flen]);
                            let id = cur.u32()? as usize;
                            let fleet = cur.u32()? as usize;
                            let hdim = cur.u32()? as usize;
                            cur.done().context("reading HELLO")?;
                            ensure!(
                                fleet == n,
                                "client expects a fleet of {fleet}, server runs {n}"
                            );
                            ensure!(hdim == dim, "client expects dim {hdim}, server runs {dim}");
                            ensure!(id < n, "client id {id} out of range for a fleet of {n}");
                            ensure!(slots[id].is_none(), "client id {id} joined twice");
                            HelloStep::Join { id, flen }
                        }
                        None if !open => HelloStep::Dead,
                        None => HelloStep::Wait,
                    }
                };
                match step {
                    HelloStep::Wait => {}
                    HelloStep::Dead => {
                        pending[i] = None;
                        self.stat(|st| st.churned += 1);
                    }
                    HelloStep::Join { id, flen } => {
                        let mut q = pending[i].take().expect("pending present");
                        q.rbuf.consume(flen);
                        slots[id] = Some((q.stream, q.rbuf));
                        joined += 1;
                        last_progress = Instant::now();
                        self.stat(|st| st.connected += 1);
                    }
                }
            }
            pending.retain(|p| p.is_some());
        }
        // connections beyond the completed fleet are shed
        self.stat(|st| st.rejected += pending.iter().flatten().count() as u64);
        drop(pending);

        let now = Instant::now();
        let conns: Vec<EvConn> = slots
            .into_iter()
            .map(|s| {
                let (stream, rbuf) = s.expect("all slots filled");
                EvConn {
                    stream,
                    rbuf,
                    scale: [0u8; 4],
                    out: None,
                    deadline: now + self.timeout,
                    open: true,
                }
            })
            .collect();
        Ok(NetTransport {
            srv: self,
            dim,
            has_comp,
            inner: RefCell::new(TransportInner {
                conns,
                staging: StagedUplink::default(),
                poller: evloop::Poller::new(),
                pslots: Vec::new(),
                round_frame: Vec::new(),
                scale_off: 0,
                round: 0,
                layout: LAYOUT_SPARSE,
                sup: Vec::new(),
                input: PoolInput::default(),
            }),
        })
    }

    /// Drive a full networked run of `spec`: accept one connection per
    /// dataset client, stream every round over the sockets through the
    /// event loop, broadcast DONE, and return the record — bit-for-bit
    /// the in-process fused run of the same spec. `on_eval` fires at
    /// every eval round (the JSON metrics line of `fedeff serve
    /// --listen`).
    pub fn serve(&self, spec: &Spec, on_eval: &mut dyn FnMut(&RoundStat)) -> Result<RunRecord> {
        ensure!(
            spec.scenario.is_none(),
            "time-aware scenarios are in-process only (the virtual clock replaces the real \
             barrier); drop [scenario] or serve without --listen"
        );
        let oracle = fleet_oracle(spec)?;
        let n = spec.dataset.clients;
        let d = oracle.dim();
        let mut alg = build_algorithm(&spec.algorithm, &oracle)?;
        let driver = build_driver(spec, n)?;
        let transport = self.accept_fleet(n, d, leaf_compressor(spec).is_some())?;
        let x0 = vec![0.5f32; d];
        let mut cb = |r: &RoundStat| on_eval(r);
        let rec = driver.run_with_transport(
            alg.as_mut(),
            &oracle,
            &transport,
            &x0,
            &spec_opts(spec),
            Some(&mut cb),
        )?;
        transport.shutdown()?;
        Ok(rec)
    }
}

// ---------------------------------------------------------------------
// client fleet
// ---------------------------------------------------------------------

/// Run the client side of a networked serve: one simulated client per
/// dataset client (each on its own thread with its own compressor
/// fork), all built from the same spec the server loaded, connecting to
/// `addr` and answering ROUND frames until DONE.
pub fn run_fleet(addr: &str, spec: &Spec) -> Result<()> {
    let ids: Vec<usize> = (0..spec.dataset.clients).collect();
    run_fleet_clients(addr, spec, &ids)
}

/// [`run_fleet`] restricted to a subset of client ids — the missing
/// ids never connect, which is how the adversarial tests stand in for
/// stalled or misbehaving fleet members while the rest of the fleet
/// behaves normally.
pub fn run_fleet_clients(addr: &str, spec: &Spec, clients: &[usize]) -> Result<()> {
    let oracle = fleet_oracle(spec)?;
    let n = spec.dataset.clients;
    let d = oracle.dim();
    let comp = leaf_compressor(spec);
    for &c in clients {
        ensure!(c < n, "fleet client id {c} out of range for {n} dataset clients");
    }
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(clients.len());
        for &c in clients {
            let oracle = &oracle;
            let comp = comp.clone();
            handles.push(scope.spawn(move || client_loop(addr, c, n, d, comp.as_ref(), oracle)));
        }
        let mut first_err = None;
        for (h, &c) in handles.into_iter().zip(clients) {
            let res = h.join().map_err(|_| anyhow::anyhow!("fleet client {c} panicked"));
            if let Err(e) = res.and_then(|r| r) {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })
}

/// One simulated client: HELLO, then execute every ROUND recipe through
/// the *same* fused pipeline the in-process workers run
/// ([`run_chunk`]), encode each channel's message with the wire codec,
/// and enforce the codec invariant (`bit_len == compressor-quoted
/// bits`) before sending.
fn client_loop(
    addr: &str,
    client: usize,
    fleet: usize,
    dim: usize,
    comp: Option<&(String, usize, usize)>,
    oracle: &RustLogReg,
) -> Result<()> {
    let stream = connect_retry(addr, Duration::from_secs(10))?;
    stream.set_nodelay();
    let mut conn = Conn::new(stream, DEFAULT_TIMEOUT)?;
    let mut hello = Vec::with_capacity(12);
    hello.extend_from_slice(&(client as u32).to_le_bytes());
    hello.extend_from_slice(&(fleet as u32).to_le_bytes());
    hello.extend_from_slice(&(dim as u32).to_le_bytes());
    write_frame(&mut conn.w, KIND_HELLO, &hello)?;
    conn.w.flush()?;

    let mut kit = FusedKit::default();
    let fork = match comp {
        Some((name, k, kp)) => Some(
            compressor_by_name(name, *k, *kp)?
                .fork()
                .with_context(|| format!("uplink compressor {name} has no sparse fork"))?,
        ),
        None => None,
    };
    let has_comp = fork.is_some();
    kit.install(fork);

    let mut input = PoolInput::default();
    input.cohort.push(client);
    input.scales.push(0.0);
    let mut out = WorkerOut::default();
    let mut frame = Vec::new();
    let mut msg = Vec::new();
    let mut w = BitWriter::new();
    let mut sv = SparseVec::default();

    loop {
        let kind = read_frame(&mut conn.r, &mut frame)
            .with_context(|| format!("client {client} reading from the coordinator"))?;
        match kind {
            KIND_DONE => return Ok(()),
            KIND_ROUND => {
                let layout = parse_round(&frame, dim, &mut input)?;
                let expect = if input.sup.is_empty() {
                    ensure!(has_comp, "unmasked round reached a compressor-less client");
                    LAYOUT_SPARSE
                } else if has_comp {
                    LAYOUT_MASKED_SPARSE
                } else {
                    LAYOUT_MASKED_RAW
                };
                ensure!(
                    layout == expect,
                    "coordinator negotiated layout {layout}, this client produces {expect}"
                );
                run_chunk(oracle, &input, &mut kit, &mut out, 0, 1, dim)?;
                let round32 = input.round as u32;
                let mut off = 0usize;
                for (ch, &len) in out.lens.iter().enumerate() {
                    let (lo, hi) = (off, off + len as usize);
                    off = hi;
                    sv.clear(dim);
                    for (&i, &v) in out.idx[lo..hi].iter().zip(&out.val[lo..hi]) {
                        sv.push(i, v);
                    }
                    w.clear();
                    match layout {
                        LAYOUT_SPARSE => codec::encode_sparse(&sv, &mut w)?,
                        LAYOUT_MASKED_RAW => codec::encode_masked_raw(&sv, &input.sup, &mut w)?,
                        LAYOUT_MASKED_SPARSE => {
                            codec::encode_masked_sparse(&sv, &input.sup, &mut w)?
                        }
                        _ => unreachable!("layout validated above"),
                    }
                    // the codec invariant, enforced on every live message
                    ensure!(
                        w.bit_len() == out.bits[ch],
                        "codec packed {} bits but the compressor quoted {} (client {client}, \
                         round {}, channel {ch})",
                        w.bit_len(),
                        out.bits[ch],
                        input.round
                    );
                    msg.clear();
                    msg.extend_from_slice(&round32.to_le_bytes());
                    msg.push(ch as u8);
                    msg.push(layout);
                    msg.extend_from_slice(&(sv.len() as u32).to_le_bytes());
                    msg.extend_from_slice(w.finish());
                    write_frame(&mut conn.w, KIND_MSG, &msg)?;
                }
                conn.w.flush()?;
            }
            other => bail!("unexpected frame kind {other} from the coordinator"),
        }
    }
}

/// Parse a ROUND frame into the client's single-slot [`PoolInput`];
/// returns the negotiated layout byte.
fn parse_round(frame: &[u8], dim: usize, input: &mut PoolInput) -> Result<u8> {
    let mut cur = Cur::new(frame);
    input.round = cur.u32()? as usize;
    input.seed = cur.u64()?;
    input.scales[0] = cur.f32()?;
    let layout = cur.u8()?;
    input.payload = match cur.u8()? {
        PAYLOAD_GRADIENT => FusedPayload::Gradient,
        PAYLOAD_LOCAL_SGD => {
            let steps = cur.u32()? as usize;
            let lr = cur.f32()?;
            let prox_mu = match cur.u8()? {
                0 => None,
                1 => Some(cur.f32()?),
                other => bail!("bad prox flag {other}"),
            };
            FusedPayload::LocalSgd { steps, lr, prox_mu }
        }
        other => bail!("unknown payload tag {other}"),
    };
    let d = cur.u32()? as usize;
    ensure!(d == dim, "round anchor dim {d} != client dim {dim}");
    input.point.clear();
    input.point.reserve(d);
    for _ in 0..d {
        input.point.push(cur.f32()?);
    }
    let nsup = cur.u32()? as usize;
    ensure!(nsup <= d, "support of {nsup} over dim {d}");
    input.sup.clear();
    input.sup.reserve(nsup);
    for _ in 0..nsup {
        input.sup.push(cur.u32()?);
    }
    ensure!(
        input.sup.windows(2).all(|p| p[0] < p[1]) && input.sup.iter().all(|&j| (j as usize) < d),
        "mask support must be strictly ascending within the model dimension"
    );
    cur.done()?;
    Ok(layout)
}
