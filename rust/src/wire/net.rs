//! The networked coordinator: real bytes between a socket fleet and the
//! fused O(k) merge, served by a readiness-driven event loop
//! (DESIGN.md §Wire).
//!
//! `fedeff serve --listen ADDR` binds a [`NetServer`] (TCP loopback or
//! a Unix domain socket; addresses are `tcp:HOST:PORT` / `uds:PATH`),
//! accepts one length-framed connection per dataset client, and drives
//! the same [`crate::coordinator::driver::Driver`] round loop as an
//! in-process run — with the client pipeline executing on the other end
//! of the sockets. A [`NetTransport`] implements the driver's
//! fused-uplink seam over a single-threaded [`super::evloop`] event
//! loop: every socket is non-blocking, each connection accumulates
//! bytes in a compacting receive window (partial-frame reassembly),
//! and complete MSG frames are decoded **on arrival** — whatever order
//! the kernel delivers them — straight into per-`(client, channel)`
//! staging slots (`StagedUplink`). Once the round is fully staged,
//! the slots are committed to the driver **in cohort order, channels
//! ascending**: the serial reference path's scatter sequence, which is
//! what keeps a networked run bit-for-bit identical to the in-process
//! fused run (losses, bits_up, bits_down, comm cost; pinned by
//! rust/tests/serve_net.rs and the serve-smoke CI job at 1024 clients).
//! Arrival order affects only *when* decode work happens; commit order
//! is fixed by the contract.
//!
//! The ROUND broadcast is encoded once per *variant* per round (dense
//! mode: exactly one shared frame); the only per-client bytes are the
//! 4 little-endian scale bytes, which travel as the middle segment of a
//! 3-segment vectored write around the shared frame — the frame itself
//! is never copied or patched per client. Writes drain through a
//! per-connection **frame queue** with explicit backpressure state
//! (`Outgoing::sent`), so a client with a full socket buffer delays
//! only its own frames, and a newly committed round's broadcast is
//! encoded and queued while earlier frames (a straggling broadcast, a
//! DONE behind it) are still draining — the pipelining half of this
//! module. Frames that arrive for an already-committed round are
//! discarded loudly (`ServeStats::stale_discarded`), never decoded.
//!
//! Under [`crate::coordinator::delta::DownlinkMode::Delta`] the driver
//! plans each round's downlink as per-receiver min(dense resync,
//! changed-coordinate delta) and this transport encodes exactly the
//! planned variants: after first contact a ROUND frame carries the
//! anchor as exact `(index, new_f32)` pairs against the version the
//! client last received (`amode = AMODE_DELTA`), with a dense resync
//! (`AMODE_DENSE`) on first contact or whenever the delta would not
//! win. Clients hold a persistent anchor + version and refuse a delta
//! whose base version they do not hold — a desync dies loudly, never
//! silently. Booked downlink bits equal encoded payload bits on both
//! the in-process and networked paths (frame headers travel unbooked).
//!
//! Buffered-async scenarios also run over the wire
//! ([`NetServer::serve`] routes `[scenario] mode = "async"` to the
//! event-loop analog of [`crate::scenario::run_buffered_async`]):
//! every client flies continuously at its own pace on
//! dispatch-counter-keyed RNG streams, the server folds a
//! staleness-weighted aggregate every `buffer` arrivals, and each
//! redispatch re-broadcasts the anchor per-client (dense or delta).
//! Virtual arrival order — not socket arrival order — decides the fold
//! sequence, which is what keeps the networked async run bit-for-bit
//! the in-process one (losses, booked bits, dispatch/apply counters).
//!
//! Fault tolerance (DESIGN.md §Faults): with `[faults] quorum` (or
//! `fedeff serve --quorum`) set, a sync round commits once at least
//! `ceil(quorum × cohort)` members delivered and every remaining member
//! was evicted on its own progress deadline or hung up — the missing
//! clients' staged slots are skipped **in cohort order** and the driver
//! drops them from the committing cohort, exactly the scenario engine's
//! mid-round dropout (booked bits cover only what actually travelled;
//! pinned bit-for-bit against an in-process scripted run). A client
//! that reconnects mid-run re-HELLOs with its id and is re-admitted
//! into its dead slot at the next round boundary (sync) or next
//! dispatch (buffered-async), with a dense anchor resync forced through
//! [`DeltaTracker::forget`]; a duplicate HELLO while the original
//! socket is live is rejected loudly by name. The [`super::chaos`]
//! layer wraps each accepted connection's I/O with deterministic,
//! seed-replayable fault injection. Without a quorum every mid-round
//! loss stays the hard, named error it always was.
//!
//! Frame layout (little-endian): `u32 len | u8 kind | payload`, where
//! `len` counts the kind byte plus the payload and is capped at
//! [`MAX_FRAME`]. Kinds: HELLO (client joins: id, fleet size, dim),
//! ROUND (server→client round recipe; the anchor travels under an
//! `amode` byte — dense `ver | f32×d`, or delta
//! `base | ver | m | packed pairs`), MSG (client→server one uplink
//! channel: round, channel, layout, pair count, bit-packed codec body,
//! zero-padded to bytes), DONE (server→fleet shutdown). Malformed,
//! truncated or oversized frames produce `anyhow` errors and a closed
//! connection — never a panic, and never a hang: every connection the
//! round is waiting on carries a progress deadline, refreshed on every
//! byte of socket progress, and a stalled client is evicted loudly (by
//! name) when *its own* deadline lapses while every other connection
//! keeps decoding.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::{self, BufReader, BufWriter, IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::bits::{BitReader, BitWriter};
use super::chaos::{ChaosConn, ChaosSpec};
use super::codec::{self, LAYOUT_MASKED_RAW, LAYOUT_MASKED_SPARSE, LAYOUT_SPARSE};
use super::evloop;
use crate::algorithms::{build_algorithm, dense_bits, FlAlgorithm, PayloadSpec, ScaleSpec};
use crate::algorithms::RunOptions;
use crate::compress::SparseVec;
use crate::config::{build_driver, build_scenario, compressor_by_name, Spec};
use crate::coordinator::delta::{DeltaRound, DeltaTracker, DownlinkMode};
use crate::coordinator::driver::{record_eval, Topology};
use crate::coordinator::fused::{run_chunk, FusedKit, FusedPayload, StagedUplink};
use crate::coordinator::{CommLedger, FusedUplink, PoolInput, WorkerOut};
use crate::data::synth::Heterogeneity;
use crate::metrics::{RoundStat, RunRecord, ScenarioStat};
use crate::oracle::logreg_rs::RustLogReg;
use crate::oracle::Oracle;
use crate::rng::Rng;
use crate::scenario::{event_rng, Mode, ScenarioSpec, Staleness, EV_COMPUTE, EV_DROP, EV_SPEED};
use crate::vecmath as vm;

/// Hard ceiling on one frame's size (kind byte + payload): 64 MiB.
pub const MAX_FRAME: u32 = 1 << 26;
/// Userspace buffer per client-side connection half, and the server's
/// per-`read` chunk (the bounded backpressure window; everything beyond
/// it waits in the kernel socket buffer).
const CONN_BUF: usize = 64 * 1024;
/// Consumed-prefix size at which a receive window compacts (memmoves
/// its live tail to the front).
const COMPACT_AT: usize = 64 * 1024;
/// Default progress deadline — a peer that stops mid-frame errors out
/// instead of hanging the round loop.
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(30);

const KIND_HELLO: u8 = 1;
const KIND_ROUND: u8 = 2;
const KIND_MSG: u8 = 3;
const KIND_DONE: u8 = 4;

/// The complete DONE frame: `len=1 | kind` and no payload.
const DONE_FRAME: [u8; 5] = [1, 0, 0, 0, KIND_DONE];

const PAYLOAD_GRADIENT: u8 = 0;
const PAYLOAD_LOCAL_SGD: u8 = 1;

/// ROUND anchor modes: the byte after the `d u32` field picks how the
/// anchor travels. Dense: `ver u64 | f32 × d` (the full model, version
/// stamped). Delta: `base u64 | ver u64 | m u32 | packed pairs` — `m`
/// (index, new_f32) pairs against the anchor of version `base`, packed
/// by [`codec::encode_anchor_delta`] and zero-padded to whole bytes
/// (the byte length is recomputed client-side from `m` and `d`, so a
/// truncated delta can never parse).
const AMODE_DENSE: u8 = 0;
const AMODE_DELTA: u8 = 1;

// ---------------------------------------------------------------------
// address grammar + stream/listener abstraction
// ---------------------------------------------------------------------

/// One connected byte stream (TCP or, on Unix, a domain socket).
pub enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
            #[cfg(unix)]
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
        })
    }

    /// Kernel-level read timeout — **client-side only** (used solely by
    /// [`Conn::new`] under the fleet's blocking `BufReader` loop, where
    /// it is the one thing standing between a silent coordinator and a
    /// client thread blocked forever). The server never calls this: its
    /// connections are nonblocking under the poller, with progress
    /// deadlines enforced per connection in the event loop instead.
    fn set_read_timeout(&self, t: Duration) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(Some(t))?,
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(Some(t))?,
        }
        Ok(())
    }

    fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nb)?,
            #[cfg(unix)]
            Stream::Unix(s) => s.set_nonblocking(nb)?,
        }
        Ok(())
    }

    /// Disable Nagle on TCP (frame latency beats batching here); a
    /// no-op for domain sockets.
    fn set_nodelay(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.set_nodelay(true);
            }
            #[cfg(unix)]
            Stream::Unix(_) => {}
        }
    }

    /// Best-effort full shutdown — used when the server gives up on a
    /// connection (quorum eviction, injected chaos drop) so the remote
    /// peer observes EOF instead of blocking on a socket the event
    /// loop merely stopped polling.
    pub(crate) fn shutdown(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }

    #[cfg(unix)]
    fn raw_fd(&self) -> evloop::RawFd {
        use std::os::unix::io::AsRawFd;
        match self {
            Stream::Tcp(s) => s.as_raw_fd(),
            Stream::Unix(s) => s.as_raw_fd(),
        }
    }

    #[cfg(not(unix))]
    fn raw_fd(&self) -> evloop::RawFd {
        0
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn write_vectored(&mut self, bufs: &[IoSlice<'_>]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write_vectored(bufs),
            #[cfg(unix)]
            Stream::Unix(s) => s.write_vectored(bufs),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// A bound accept socket. `tcp:HOST:PORT` binds TCP with `SO_REUSEADDR`
/// (port 0 picks a free port — read the real one back from
/// [`Listener::local_addr`]); `uds:PATH` binds a Unix domain socket
/// (stale socket files are replaced, and the path is unlinked again
/// when the listener drops).
pub enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

impl Listener {
    pub fn bind(addr: &str) -> Result<Listener> {
        if let Some(hostport) = addr.strip_prefix("tcp:") {
            let l = evloop::bind_tcp_reuseaddr(hostport)
                .with_context(|| format!("binding tcp listener on {hostport}"))?;
            return Ok(Listener::Tcp(l));
        }
        if let Some(path) = addr.strip_prefix("uds:") {
            #[cfg(unix)]
            {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .with_context(|| format!("binding unix socket {path}"))?;
                return Ok(Listener::Unix(l, PathBuf::from(path)));
            }
            #[cfg(not(unix))]
            bail!("uds: addresses need a Unix platform; use tcp:HOST:PORT");
        }
        bail!("address {addr:?} is neither tcp:HOST:PORT nor uds:PATH")
    }

    /// The canonical address peers connect to (resolves `tcp:...:0` to
    /// the picked port).
    pub fn local_addr(&self) -> Result<String> {
        Ok(match self {
            Listener::Tcp(l) => format!("tcp:{}", l.local_addr()?),
            #[cfg(unix)]
            Listener::Unix(_, path) => format!("uds:{}", path.display()),
        })
    }

    fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb)?,
            #[cfg(unix)]
            Listener::Unix(l, _) => l.set_nonblocking(nb)?,
        }
        Ok(())
    }

    /// Accept one connection if the queue is non-empty. Transient
    /// accept failures (`WouldBlock`, `EINTR`, a peer that aborted
    /// between readiness and accept) report "nothing to accept" — the
    /// next readiness lap retries.
    fn accept_nonblocking(&self) -> Result<Option<Stream>> {
        let r = match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l, _) => l.accept().map(|(s, _)| Stream::Unix(s)),
        };
        match r {
            Ok(s) => Ok(Some(s)),
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::Interrupted
                        | io::ErrorKind::ConnectionAborted
                ) =>
            {
                Ok(None)
            }
            Err(e) => Err(e.into()),
        }
    }

    #[cfg(unix)]
    fn raw_fd(&self) -> evloop::RawFd {
        use std::os::unix::io::AsRawFd;
        match self {
            Listener::Tcp(l) => l.as_raw_fd(),
            Listener::Unix(l, _) => l.as_raw_fd(),
        }
    }

    #[cfg(not(unix))]
    fn raw_fd(&self) -> evloop::RawFd {
        0
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        // socket-lifecycle hygiene: a dead server must not leave a
        // stale socket file for the next bind to trip over
        #[cfg(unix)]
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Connect to a `tcp:`/`uds:` address.
pub fn connect(addr: &str) -> Result<Stream> {
    if let Some(hostport) = addr.strip_prefix("tcp:") {
        return Ok(Stream::Tcp(
            TcpStream::connect(hostport).with_context(|| format!("connecting to {hostport}"))?,
        ));
    }
    if let Some(path) = addr.strip_prefix("uds:") {
        #[cfg(unix)]
        return Ok(Stream::Unix(
            UnixStream::connect(path).with_context(|| format!("connecting to {path}"))?,
        ));
        #[cfg(not(unix))]
        bail!("uds: addresses need a Unix platform; use tcp:HOST:PORT");
    }
    bail!("address {addr:?} is neither tcp:HOST:PORT nor uds:PATH")
}

const BACKOFF_BASE_MS: u64 = 10;
const BACKOFF_CAP_MS: u64 = 640;
/// `10 ms << 6 == 640 ms` — doublings beyond this only saturate.
const BACKOFF_DOUBLINGS: u32 = 6;

/// Capped exponential backoff with deterministic jitter for client
/// (re)connect attempts: attempt `k` sleeps `min(10 ms << k, 640 ms)`
/// scaled by a jitter factor in `[0.5, 1.0)` drawn from a seed-keyed
/// stream. Deterministic per seed (the unit tests pin the schedule),
/// shared by the initial fleet connect and mid-run reconnects, and
/// seeded per client id so a 1024-client retry storm spreads out
/// instead of marching in a fixed 20 ms phalanx.
pub struct Backoff {
    attempt: u32,
    rng: Rng,
}

impl Backoff {
    pub fn new(seed: u64) -> Backoff {
        Backoff { attempt: 0, rng: Rng::new(seed ^ 0xBAC0_FF5E_0D1C_E5ED) }
    }

    /// The delay before the next attempt; advances the schedule.
    pub fn next_delay(&mut self) -> Duration {
        let exp = BACKOFF_BASE_MS << self.attempt.min(BACKOFF_DOUBLINGS);
        self.attempt = self.attempt.saturating_add(1);
        let jitter = 0.5 + 0.5 * self.rng.f64_unit();
        Duration::from_nanos((exp.min(BACKOFF_CAP_MS) as f64 * 1_000_000.0 * jitter) as u64)
    }

    /// Restart the exponential schedule (a successful connect resets
    /// the clock); the jitter stream continues rather than repeating.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

/// [`connect`] with retries while the server is still binding/accepting
/// (the fleet usually races the coordinator's startup), paced by the
/// caller's [`Backoff`] — also the mid-run reconnect path.
fn connect_retry(addr: &str, budget: Duration, backoff: &mut Backoff) -> Result<Stream> {
    let t0 = Instant::now();
    loop {
        match connect(addr) {
            Ok(s) => {
                backoff.reset();
                return Ok(s);
            }
            Err(e) if t0.elapsed() < budget => {
                let _ = e;
                std::thread::sleep(backoff.next_delay());
            }
            Err(e) => return Err(e),
        }
    }
}

// ---------------------------------------------------------------------
// frames
// ---------------------------------------------------------------------

/// One blocking client-side connection: buffered reader/writer halves
/// over cloned handles. (The server side is non-blocking and uses
/// [`RecvBuf`] instead.)
struct Conn {
    r: BufReader<Stream>,
    w: BufWriter<Stream>,
}

impl Conn {
    fn new(s: Stream, timeout: Duration) -> Result<Conn> {
        s.set_read_timeout(timeout)?;
        let rh = s.try_clone()?;
        Ok(Conn {
            r: BufReader::with_capacity(CONN_BUF, rh),
            w: BufWriter::with_capacity(CONN_BUF, s),
        })
    }
}

fn write_frame(w: &mut impl Write, kind: u8, payload: &[u8]) -> Result<()> {
    let len = payload.len() as u64 + 1;
    ensure!(len <= MAX_FRAME as u64, "frame of {len} bytes exceeds MAX_FRAME ({MAX_FRAME})");
    w.write_all(&(len as u32).to_le_bytes())?;
    w.write_all(&[kind])?;
    w.write_all(payload)?;
    Ok(())
}

/// Read one frame into `buf` (payload only); returns the kind byte.
/// Zero-length and oversized frames are protocol errors.
fn read_frame(r: &mut impl Read, buf: &mut Vec<u8>) -> Result<u8> {
    let mut hdr = [0u8; 4];
    r.read_exact(&mut hdr).context("reading frame header")?;
    let len = u32::from_le_bytes(hdr);
    ensure!(len >= 1, "zero-length frame");
    ensure!(len <= MAX_FRAME, "oversized frame: {len} bytes (max {MAX_FRAME})");
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind).context("reading frame kind")?;
    buf.clear();
    buf.resize(len as usize - 1, 0);
    r.read_exact(buf).context("reading frame payload")?;
    Ok(kind[0])
}

/// Inspect the head of a receive window for one complete frame without
/// consuming it: `Ok(Some((kind, total_len)))` when `data[..total_len]`
/// is a whole frame (payload at `data[5..total_len]`), `Ok(None)` when
/// more bytes must arrive, and an error for frames that can never
/// become valid (zero-length, oversized) — checked from the 4 header
/// bytes alone, before any buffering commitment.
fn peek_frame(data: &[u8]) -> Result<Option<(u8, usize)>> {
    if data.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(data[..4].try_into().expect("4 bytes"));
    ensure!(len >= 1, "zero-length frame");
    ensure!(len <= MAX_FRAME, "oversized frame: {len} bytes (max {MAX_FRAME})");
    let total = 4 + len as usize;
    if data.len() < total {
        return Ok(None);
    }
    Ok(Some((data[4], total)))
}

/// Bounds-checked little-endian cursor over a frame payload.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).context("frame length overflow")?;
        ensure!(
            end <= self.buf.len(),
            "frame truncated: wanted {n} bytes past offset {}",
            self.pos
        );
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn rest(self) -> &'a [u8] {
        &self.buf[self.pos..]
    }

    fn done(&self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "{} trailing bytes in frame",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------
// shared spec plumbing (the config path `run`, `serve` and the fleet
// all resolve identically)
// ---------------------------------------------------------------------

/// Build the pure-Rust logreg oracle a spec describes — the exact
/// dataset construction `fedeff run` uses (profile, clients,
/// heterogeneity, regularizer, seed), so server, fleet and in-process
/// comparisons all train on identical data.
pub fn fleet_oracle(spec: &Spec) -> Result<RustLogReg> {
    let ds = &spec.dataset;
    ensure!(ds.kind == "logreg", "networked serving drives the logreg substrate, not {}", ds.kind);
    let het = match ds.heterogeneity.as_deref() {
        Some("iid") => Heterogeneity::Iid,
        Some("class") => Heterogeneity::ClassSkew(0.85),
        _ => Heterogeneity::FeatureShift(0.5),
    };
    let (d, m) = crate::data::synth::logreg_profile(&ds.profile)
        .ok_or_else(|| anyhow::anyhow!("unknown logreg profile {}", ds.profile))?;
    let mut rng = crate::rng(spec.experiment.seed);
    let data = crate::data::synth::logreg_dataset(d, m, ds.clients, het, 0.3, &mut rng);
    Ok(RustLogReg::new(data, ds.reg))
}

/// The effective leaf (client-out) uplink compressor of a spec —
/// mirrors the driver's resolution (a `[links.up.l0]` edge under an
/// executed tree overrides the flat `[compressor] up`).
pub fn leaf_compressor(spec: &Spec) -> Option<(String, usize, usize)> {
    if spec.topology.as_ref().is_some_and(|t| t.levels.is_some()) {
        if let Some(Some(e)) = spec.links.up_edges.first() {
            return Some((e.kind.clone(), e.k, e.k_prime));
        }
    }
    spec.links.up.as_ref().map(|u| (u.clone(), spec.links.k, spec.links.k_prime))
}

/// [`RunOptions`] a spec describes (the serve path's view).
fn spec_opts(spec: &Spec) -> RunOptions {
    RunOptions {
        rounds: spec.experiment.rounds,
        eval_every: spec.experiment.eval_every,
        seed: spec.experiment.seed,
        ..Default::default()
    }
}

/// Run a spec in-process on the fused worker-pool path, streaming eval
/// rounds — the reference a networked run must match bit for bit.
/// Specs with a `[scenario]` section run under the virtual clock
/// (buffered-async included), replaying the recorded eval rounds
/// through `on_eval` after the run.
pub fn run_in_process(spec: &Spec, on_eval: &mut dyn FnMut(&RoundStat)) -> Result<RunRecord> {
    let oracle = fleet_oracle(spec)?;
    let d = oracle.dim();
    let mut alg = build_algorithm(&spec.algorithm, &oracle)?;
    let driver = build_driver(spec, spec.dataset.clients)?;
    let x0 = vec![0.5f32; d];
    match &spec.scenario {
        Some(sc) => {
            let scen = build_scenario(sc)?;
            let rec =
                driver.run_scenario_parallel(alg.as_mut(), &oracle, &scen, &x0, &spec_opts(spec))?;
            for r in &rec.rounds {
                on_eval(r);
            }
            Ok(rec)
        }
        None => driver.run_parallel_streaming(
            alg.as_mut(),
            &oracle,
            &x0,
            &spec_opts(spec),
            |r| on_eval(r),
        ),
    }
}

// ---------------------------------------------------------------------
// server: event loop over non-blocking connections
// ---------------------------------------------------------------------

/// Per-connection receive window: bytes land at the tail, complete
/// frames are consumed from the head, and a partial frame simply stays
/// buffered until its remaining bytes arrive (reassembly across any
/// number of reads — a peer may trickle one byte at a time). The
/// consumed prefix slides forward without copying until it outgrows
/// [`COMPACT_AT`], then the live tail is compacted to the front; frame
/// payloads are decoded by *borrowing* straight out of this buffer, so
/// the steady-state round loop does no per-frame allocation at all.
#[derive(Default)]
pub(crate) struct RecvBuf {
    buf: Vec<u8>,
    start: usize,
}

impl RecvBuf {
    fn data(&self) -> &[u8] {
        &self.buf[self.start..]
    }

    fn is_empty(&self) -> bool {
        self.start == self.buf.len()
    }

    fn consume(&mut self, n: usize) {
        self.start += n;
        debug_assert!(self.start <= self.buf.len());
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        } else if self.start >= COMPACT_AT {
            self.buf.drain(..self.start);
            self.start = 0;
        }
    }

    /// One non-blocking `read` of up to [`CONN_BUF`] bytes into the
    /// tail; returns the byte count (0 = EOF) or the raw I/O error.
    fn fill(&mut self, stream: &mut Stream) -> io::Result<usize> {
        self.fill_max(stream, CONN_BUF)
    }

    /// [`RecvBuf::fill`] capped at `max` bytes — the chaos layer caps
    /// reads at fault-window boundaries so injected faults land at
    /// exact, replayable byte offsets.
    pub(crate) fn fill_max(&mut self, stream: &mut Stream, max: usize) -> io::Result<usize> {
        let len = self.buf.len();
        self.buf.resize(len + max.min(CONN_BUF), 0);
        match stream.read(&mut self.buf[len..]) {
            Ok(n) => {
                self.buf.truncate(len + n);
                Ok(n)
            }
            Err(e) => {
                self.buf.truncate(len);
                Err(e)
            }
        }
    }

    /// Flip the top bit of the first byte of the `n` bytes most
    /// recently filled — the chaos layer's bit-flip fault.
    pub(crate) fn corrupt_tail(&mut self, n: usize) {
        let l = self.buf.len();
        self.buf[l - n] ^= 0x80;
    }
}

/// A broadcast frame draining through the event loop; `sent` is the
/// write-backpressure cursor (bytes already accepted by the kernel).
/// `Frame` carries an index into the transport's per-round frame pool
/// (dense mode: one shared frame; delta mode: one per variant; async
/// mode: one per client).
enum Outgoing {
    Frame { idx: usize, sent: usize },
    Done { sent: usize },
}

/// One accepted (post-HELLO) connection in the event loop.
struct EvConn {
    stream: Stream,
    rbuf: RecvBuf,
    /// This client's 4 little-endian scale bytes — the middle segment
    /// of its vectored ROUND write, in place of the shared frame's
    /// zeroed hole.
    scale: [u8; 4],
    /// Queued broadcast frames, drained front-first in order — a new
    /// round's frame (or the shutdown DONE) enqueues behind whatever
    /// is still draining instead of clobbering it.
    out: VecDeque<Outgoing>,
    /// Progress deadline: refreshed on every byte read or written.
    /// Consulted only while the round is actually waiting on this
    /// connection.
    deadline: Instant,
    /// False once EOF or a hard I/O error was observed.
    open: bool,
    /// Fault-injection state wrapping this connection's I/O
    /// ([`NetServer::chaos`]); `None` runs the bytes straight through.
    chaos: Option<ChaosConn>,
}

/// Live serve counters, readable via [`NetServer::stats`] (the
/// `--metrics` JSON line and the adversarial tests' progress probes).
#[derive(Clone, Default)]
pub struct ServeStats {
    /// Bytes read off client sockets (frames and fragments alike).
    pub bytes_in: u64,
    /// Bytes written to client sockets (ROUND broadcasts + DONE).
    pub bytes_out: u64,
    /// MSG frames decoded and staged.
    pub frames_in: u64,
    /// ROUND frames enqueued (rounds × cohort size).
    pub rounds_broadcast: u64,
    /// Connections that completed HELLO and are still open.
    pub connected: usize,
    /// Pre-HELLO connections evicted on their idle deadline.
    pub evicted: u64,
    /// Pre-HELLO connections that hung up on their own (churn).
    pub churned: u64,
    /// Connections shed: beyond `--max-clients`, or arriving after the
    /// fleet was already complete.
    pub rejected: u64,
    /// Deepest per-connection broadcast queue observed (1 = no frame
    /// ever waited behind another; >1 = pipelined rounds overlapped a
    /// still-draining frame).
    pub max_queue_depth: u64,
    /// MSG frames for an already-committed round, discarded loudly
    /// without decoding (stragglers racing the shutdown drain, or a
    /// late answer to a superseded dispatch).
    pub stale_discarded: u64,
    /// Sync rounds committed below full strength: at least the quorum
    /// delivered, the missing cohort members skipped (quorum mode
    /// only; zero without `--quorum`).
    pub quorum_rounds: u64,
    /// Mid-run re-HELLOs admitted into a dead client's slot.
    pub reconnects: u64,
    /// Dense anchor resyncs forced by a reconnect admission (the
    /// readmitted replica's acked version is forgotten, so its next
    /// downlink is the full model).
    pub resyncs: u64,
    /// Faults injected by the chaos layer: drops, stalls, delays,
    /// truncations and bit flips ([`ChaosSpec`]).
    pub faults_injected: u64,
}

/// What one [`pump`] call runs the event loop for.
#[derive(Clone, Copy, PartialEq)]
enum Until {
    /// One zero-timeout lap: start whatever I/O is ready, never block.
    Opportunistic,
    /// Every queued broadcast frame fully written.
    WritesFlushed,
    /// The dispatched round fully staged (writes drain on the way).
    StagingComplete,
}

/// Copyable slice of the round context MSG validation echoes against.
#[derive(Clone, Copy)]
struct RoundMeta {
    round: usize,
    layout: u8,
}

/// Mutable event-loop state behind [`NetTransport`]'s interior
/// mutability (the driver's fused seam takes `&self`).
struct TransportInner {
    conns: Vec<EvConn>,
    staging: StagedUplink,
    poller: evloop::Poller,
    /// Poll-slot → connection-id map, rebuilt each lap (slot 0 is the
    /// listener).
    pslots: Vec<usize>,
    /// The round's ROUND frame pool (header + body each), encoded once
    /// per distinct broadcast body; per-client writes splice each
    /// connection's scale bytes over the hole at `scale_off` (the same
    /// fixed offset in every variant). Dense mode uses one shared
    /// frame; delta mode one per [`DeltaRound`] variant; async mode one
    /// per client.
    frames: Vec<Vec<u8>>,
    /// Bit-packing scratch for delta-variant encoding (reused across
    /// rounds; dense-only runs never touch it).
    wbuf: BitWriter,
    scale_off: usize,
    round: usize,
    layout: u8,
    /// True while the run is over and queued DONEs drain: every
    /// arriving MSG is a straggler, discarded loudly instead of parsed
    /// against a round that no longer exists.
    draining: bool,
    sup: Vec<u32>,
    input: PoolInput,
    /// Mid-run reconnect handshakes in progress (quorum mode only) —
    /// polled alongside the fleet, evicted on their own idle deadline.
    pending: Vec<Option<Pending>>,
    /// Completed re-HELLOs awaiting installation into their dead slot
    /// at the next round boundary (sync) or dispatch lap (async).
    rejoins: Vec<(usize, EvConn)>,
    /// Cohort clients whose slots the last quorum commit skipped —
    /// drained by the driver's casualty sweep.
    casualties: Vec<usize>,
    /// Per-slot connection generation (bumped on each readmission) —
    /// keys the chaos layer's fresh fault streams for a reconnected
    /// socket.
    gens: Vec<u64>,
}

/// The driver-facing side of an accepted fleet: implements the fused
/// uplink seam over the event loop — arrival-order decode into
/// `StagedUplink`, cohort-order commit.
pub struct NetTransport<'a> {
    srv: &'a NetServer,
    dim: usize,
    has_comp: bool,
    inner: RefCell<TransportInner>,
}

impl NetTransport<'_> {
    /// Broadcast DONE to every open connection and drain — the fleet's
    /// clean-shutdown signal. DONE enqueues *behind* any frame still
    /// draining (an async straggler's last redispatch, a pipelined
    /// round's tail), and MSG frames arriving during the drain are
    /// stragglers by definition — discarded loudly, never decoded.
    pub fn shutdown(&self) -> Result<()> {
        let mut guard = self.inner.borrow_mut();
        let inner = &mut *guard;
        inner.draining = true;
        let now = Instant::now();
        for c in inner.conns.iter_mut() {
            if c.open {
                c.out.push_back(Outgoing::Done { sent: 0 });
                self.srv.stat(|s| s.max_queue_depth = s.max_queue_depth.max(c.out.len() as u64));
                c.deadline = now + self.srv.timeout;
            }
        }
        pump(self.srv, inner, self.dim, Until::WritesFlushed).context("broadcasting DONE")
    }
}

/// Encode one ROUND frame into `buf`: length hole, recipe header, the
/// anchor under its `amode`, the mask support. `down = None` is the
/// pure dense downlink (version-stamped with the round counter);
/// `Some((plan, v))` encodes variant `v` of a [`DeltaRound`] — a dense
/// resync or a changed-coordinate delta whose packed bits are enforced
/// equal to the bits the plan books. Returns the scale-hole offset,
/// which sits at the same fixed position in every variant (after
/// len/kind/round/seed), so the per-client 3-segment scale splice
/// never depends on which frame a client gets.
fn encode_round_frame(
    buf: &mut Vec<u8>,
    inp: &PoolInput,
    layout: u8,
    dim: usize,
    down: Option<(&DeltaRound, usize)>,
    w: &mut BitWriter,
) -> Result<usize> {
    buf.clear();
    buf.extend_from_slice(&[0u8; 4]); // length, patched below
    buf.push(KIND_ROUND);
    buf.extend_from_slice(&u32::try_from(inp.round).context("round exceeds u32")?.to_le_bytes());
    buf.extend_from_slice(&inp.seed.to_le_bytes());
    let scale_off = buf.len();
    buf.extend_from_slice(&0f32.to_le_bytes());
    buf.push(layout);
    match inp.payload {
        FusedPayload::Gradient => buf.push(PAYLOAD_GRADIENT),
        FusedPayload::LocalSgd { steps, lr, prox_mu } => {
            buf.push(PAYLOAD_LOCAL_SGD);
            buf.extend_from_slice(
                &u32::try_from(steps).context("local steps exceed u32")?.to_le_bytes(),
            );
            buf.extend_from_slice(&lr.to_le_bytes());
            match prox_mu {
                Some(mu) => {
                    buf.push(1);
                    buf.extend_from_slice(&mu.to_le_bytes());
                }
                None => buf.push(0),
            }
        }
        FusedPayload::Scaffold { .. } => bail!(
            "stateful (Scaffold) payloads cannot be served over the wire: the control \
             rows live in server memory"
        ),
        FusedPayload::None => bail!("networked round dispatched without a payload recipe"),
    }
    buf.extend_from_slice(&(dim as u32).to_le_bytes());
    let dense_body = |buf: &mut Vec<u8>, ver: u64| {
        buf.push(AMODE_DENSE);
        buf.extend_from_slice(&ver.to_le_bytes());
        for &x in &inp.point {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    };
    match down {
        None => dense_body(buf, inp.round as u64),
        Some((plan, v)) => match plan.variant(v).base {
            None => dense_body(buf, plan.version),
            Some(base) => {
                let coords = plan.coords_of(v);
                buf.push(AMODE_DELTA);
                buf.extend_from_slice(&base.to_le_bytes());
                buf.extend_from_slice(&plan.version.to_le_bytes());
                buf.extend_from_slice(&(coords.len() as u32).to_le_bytes());
                w.clear();
                codec::encode_anchor_delta(coords, &inp.point, w)?;
                // the downlink codec invariant: encoded payload bits ==
                // the bits the driver books for this variant
                ensure!(
                    w.bit_len() == plan.bits_of(v),
                    "delta variant packs {} bits but the plan books {}",
                    w.bit_len(),
                    plan.bits_of(v)
                );
                buf.extend_from_slice(w.finish());
            }
        },
    }
    buf.extend_from_slice(&(inp.sup.len() as u32).to_le_bytes());
    for &j in &inp.sup {
        buf.extend_from_slice(&j.to_le_bytes());
    }
    let len = buf.len() as u64 - 4;
    ensure!(len <= MAX_FRAME as u64, "ROUND frame of {len} bytes exceeds MAX_FRAME");
    buf[..4].copy_from_slice(&(len as u32).to_le_bytes());
    Ok(scale_off)
}

impl FusedUplink for NetTransport<'_> {
    fn fused_dispatch(
        &self,
        cohort: &[usize],
        _groups: Option<&[usize]>,
        channels: usize,
        down: Option<&DeltaRound>,
        fill: &mut dyn FnMut(&mut PoolInput),
    ) -> Result<()> {
        let mut guard = self.inner.borrow_mut();
        let inner = &mut *guard;
        let n = inner.conns.len();
        inner.input.cohort.clear();
        inner.input.cohort.extend_from_slice(cohort);
        fill(&mut inner.input);
        let inp = &inner.input;
        ensure!(inp.point.len() == self.dim, "round anchor has the wrong dimension");
        ensure!(inp.scales.len() == cohort.len(), "round scales do not cover the cohort");
        let layout = if inp.sup.is_empty() {
            ensure!(self.has_comp, "an unmasked networked round needs an uplink compressor");
            LAYOUT_SPARSE
        } else if self.has_comp {
            LAYOUT_MASKED_SPARSE
        } else {
            LAYOUT_MASKED_RAW
        };
        inner.layout = layout;
        inner.round = inp.round;
        inner.sup.clear();
        inner.sup.extend_from_slice(&inp.sup);
        inner.staging.begin_round(cohort, channels, n);
        if let Some(plan) = down {
            ensure!(
                plan.assign.len() == cohort.len(),
                "delta plan assigns {} receivers for a cohort of {}",
                plan.assign.len(),
                cohort.len()
            );
        }

        // encode the round's frame pool — one frame per distinct
        // broadcast body, never re-patched per client (the scale hole
        // stays zeroed; each connection's 4 scale bytes are spliced in
        // by the vectored write). Delta-mode receivers sharing a base
        // version share the encoded frame bytes.
        let nframes = down.map_or(1, |p| p.n_variants());
        if inner.frames.len() < nframes {
            inner.frames.resize_with(nframes, Vec::new);
        }
        let mut scale_off = inner.scale_off;
        for v in 0..nframes {
            scale_off = encode_round_frame(
                &mut inner.frames[v],
                &inner.input,
                layout,
                self.dim,
                down.map(|p| (p, v)),
                &mut inner.wbuf,
            )?;
        }
        inner.scale_off = scale_off;

        let now = Instant::now();
        let mut maxq = 0u64;
        for (p, &client) in cohort.iter().enumerate() {
            let c = inner
                .conns
                .get_mut(client)
                .with_context(|| format!("cohort client {client} has no connection"))?;
            ensure!(
                c.open,
                "cohort client {client} disconnected in an earlier round; cannot dispatch \
                 round {}",
                inp.round
            );
            c.scale = inp.scales[p].to_le_bytes();
            let idx = down.map_or(0, |plan| plan.assign[p] as usize);
            c.out.push_back(Outgoing::Frame { idx, sent: 0 });
            maxq = maxq.max(c.out.len() as u64);
            c.deadline = now + self.srv.timeout;
        }
        self.srv.stat(|s| {
            s.rounds_broadcast += cohort.len() as u64;
            s.max_queue_depth = s.max_queue_depth.max(maxq);
        });

        // adversarially early bytes (a peer answering before its ROUND
        // even went out) may already sit in a receive window; surface
        // them now so they fail loudly instead of idling untouched
        {
            let TransportInner { conns, staging, sup, round, layout, draining, .. } = &mut *inner;
            let meta = RoundMeta { round: *round, layout: *layout };
            for (id, c) in conns.iter_mut().enumerate() {
                if c.open && !c.rbuf.is_empty() {
                    parse_msg_frames(self.srv, c, id, staging, meta, sup, self.dim, *draining)?;
                }
            }
        }
        // start the broadcast on whatever sockets are ready right now;
        // the rest drains during the visit-phase event loop
        pump(self.srv, inner, self.dim, Until::Opportunistic)
    }

    fn fused_visit(
        &self,
        cohort: &[usize],
        channels: usize,
        visit: &mut dyn FnMut(usize, usize, &[u32], &[f32], u64) -> Result<()>,
    ) -> Result<()> {
        let mut guard = self.inner.borrow_mut();
        let inner = &mut *guard;
        ensure!(
            channels == inner.staging.channels(),
            "visit expects {channels} channels but the dispatch staged {}",
            inner.staging.channels()
        );
        pump(self.srv, inner, self.dim, Until::StagingComplete)?;
        let Some(q) = self.srv.quorum else {
            return inner.staging.commit(cohort, visit);
        };
        // quorum-complete commit: survivors in cohort order, the lost
        // members' slots skipped wholly (no partial channels, no booked
        // bits) and reported as this round's casualties
        let TransportInner { staging, casualties, round, .. } = inner;
        staging.commit_partial(cohort, casualties, visit)?;
        for p in casualties.iter_mut() {
            *p = cohort[*p];
        }
        let delivered = cohort.len() - casualties.len();
        let need = ((q * cohort.len() as f64).ceil() as usize).max(1);
        ensure!(
            delivered >= need,
            "round {}: quorum missed — {delivered}/{} cohort clients delivered (quorum {q} \
             needs {need}); lost clients {:?}",
            *round,
            cohort.len(),
            casualties
        );
        if !casualties.is_empty() {
            self.srv.stat(|s| s.quorum_rounds += 1);
        }
        Ok(())
    }

    /// Round-boundary fault hook (quorum mode only): install completed
    /// mid-run re-HELLOs into their dead slots — reporting them in
    /// `rejoined` so the driver forces a dense downlink resync — then
    /// trim the cohort to reachable clients, the socket twin of the
    /// scenario engine's availability trim.
    fn begin_round(
        &self,
        round: usize,
        cohort: &mut Vec<usize>,
        rejoined: &mut Vec<usize>,
    ) -> Result<()> {
        if self.srv.quorum.is_none() {
            return Ok(());
        }
        let mut guard = self.inner.borrow_mut();
        let inner = &mut *guard;
        // one zero-timeout lap so a re-HELLO that completed since the
        // last pump is admitted even on an otherwise idle socket set
        pump(self.srv, inner, self.dim, Until::Opportunistic)?;
        let TransportInner { conns, rejoins, .. } = inner;
        let now = Instant::now();
        for (id, mut conn) in rejoins.drain(..) {
            conn.deadline = now + self.srv.timeout;
            conns[id] = conn;
            rejoined.push(id);
            self.srv.stat(|s| {
                s.connected += 1;
                s.reconnects += 1;
                s.resyncs += 1;
            });
        }
        cohort.retain(|&c| conns[c].open);
        ensure!(
            !cohort.is_empty(),
            "round {round}: every cohort client is disconnected; a quorum of zero clients \
             cannot train"
        );
        Ok(())
    }

    fn casualties(&self, out: &mut Vec<usize>) {
        out.extend(self.inner.borrow_mut().casualties.drain(..));
    }
}

/// One call into the event loop: poll readiness over the listener and
/// every open connection, then accept/read/decode/write whatever is
/// ready, looping until the `until` condition holds. Deadlines are
/// enforced *per connection* and only for connections the condition is
/// actually waiting on — a stalled client is named and evicted when its
/// own deadline lapses, while every other connection keeps reading,
/// decoding and staging in the meantime.
fn pump(srv: &NetServer, inner: &mut TransportInner, dim: usize, until: Until) -> Result<()> {
    let TransportInner {
        conns,
        staging,
        poller,
        pslots,
        frames,
        scale_off,
        round,
        layout,
        draining,
        sup,
        pending,
        rejoins,
        gens,
        ..
    } = inner;
    let meta = RoundMeta { round: *round, layout: *layout };
    let scale_off = *scale_off;
    let draining = *draining;
    let quorum = srv.quorum.is_some();
    loop {
        let writes_pending = conns.iter().any(|c| c.open && !c.out.is_empty());
        let done = match until {
            Until::Opportunistic => false,
            Until::WritesFlushed => !writes_pending,
            // staging completeness alone closes the barrier: a cohort
            // member can only have answered after fully receiving its
            // ROUND, so its own frame has necessarily drained — and any
            // *other* queued frame (a non-awaited straggler's) may keep
            // draining into the next round's event loop (pipelining).
            // Under a quorum the barrier also closes once every still-
            // incomplete cohort member is gone — the commit decides
            // whether enough survived.
            Until::StagingComplete => {
                staging.is_complete()
                    || (quorum
                        && conns.iter().enumerate().all(|(id, c)| {
                            staging
                                .cohort_pos(id)
                                .is_none_or(|p| staging.client_complete(p) || !c.open)
                        }))
            }
        };
        if done {
            return Ok(());
        }

        // deadline sweep over the connections this call waits on
        let now = Instant::now();
        let mut next_deadline: Option<Instant> = None;
        for (id, c) in conns.iter_mut().enumerate() {
            if !c.open {
                continue;
            }
            let awaited = !c.out.is_empty()
                || (until == Until::StagingComplete
                    && staging.cohort_pos(id).is_some_and(|p| !staging.client_complete(p)));
            if !awaited {
                continue;
            }
            if now >= c.deadline {
                if quorum {
                    // quorum mode: a stalled client costs itself the
                    // round, not the fleet the run
                    eprintln!(
                        "[fedeff] evicting client {id}: no socket progress within {:?} \
                         (round {})",
                        srv.timeout,
                        meta.round
                    );
                    c.open = false;
                    c.stream.shutdown();
                    srv.stat(|st| {
                        st.evicted += 1;
                        st.connected = st.connected.saturating_sub(1);
                    });
                    continue;
                }
                bail!(
                    "client {id} stalled: no socket progress within {:?} (round {}); evicting \
                     it and aborting the round — all other connections kept their own deadlines",
                    srv.timeout,
                    meta.round
                );
            }
            next_deadline = Some(next_deadline.map_or(c.deadline, |d| d.min(c.deadline)));
        }
        for p in pending.iter_mut() {
            if p.as_ref().is_some_and(|q| now >= q.deadline) {
                *p = None;
                srv.stat(|st| st.evicted += 1);
            }
        }
        pending.retain(|p| p.is_some());
        for p in pending.iter().flatten() {
            next_deadline = Some(next_deadline.map_or(p.deadline, |d| d.min(p.deadline)));
        }

        poller.clear();
        pslots.clear();
        poller.push(srv.listener.raw_fd(), evloop::Interest { read: true, write: false });
        pslots.push(usize::MAX);
        for (id, c) in conns.iter().enumerate() {
            if !c.open {
                continue;
            }
            let interest = evloop::Interest { read: true, write: !c.out.is_empty() };
            poller.push(c.stream.raw_fd(), interest);
            pslots.push(id);
        }
        for (i, p) in pending.iter().enumerate() {
            if let Some(p) = p {
                poller.push(p.stream.raw_fd(), evloop::Interest { read: true, write: false });
                pslots.push(PEND_BASE + i);
            }
        }
        let timeout = match until {
            Until::Opportunistic => Duration::ZERO,
            _ => next_deadline
                .map_or(Duration::from_millis(100), |d| d.saturating_duration_since(now)),
        };
        poller.wait(timeout)?;

        for (slot, &id) in pslots.iter().enumerate() {
            let rd = poller.readiness(slot);
            if !(rd.readable || rd.writable || rd.closed) {
                continue;
            }
            if id == usize::MAX {
                accept_churn(srv, pending, quorum)?;
                continue;
            }
            if id >= PEND_BASE {
                reconnect_step(srv, &mut pending[id - PEND_BASE], conns, rejoins, gens, dim);
                continue;
            }
            let c = &mut conns[id];
            if c.open && !c.out.is_empty() && (rd.writable || rd.closed) {
                drain_conn_out(srv, c, id, frames, scale_off, quorum)?;
            }
            if c.open && (rd.readable || rd.closed) {
                loop {
                    let r = chaos_fill(srv, c);
                    match r {
                        Ok(0) => {
                            c.open = false;
                            srv.stat(|st| st.connected = st.connected.saturating_sub(1));
                            break;
                        }
                        Ok(n) => {
                            c.deadline = Instant::now() + srv.timeout;
                            srv.stat(|st| st.bytes_in += n as u64);
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                        Err(e) => {
                            let _ = e;
                            c.open = false;
                            srv.stat(|st| st.connected = st.connected.saturating_sub(1));
                            break;
                        }
                    }
                }
                parse_msg_frames(srv, c, id, staging, meta, sup, dim, draining)?;
                if !c.open {
                    let awaited = !c.out.is_empty()
                        || staging.cohort_pos(id).is_some_and(|p| !staging.client_complete(p));
                    if quorum {
                        if awaited {
                            eprintln!(
                                "[fedeff] client {id} hung up mid-round (round {}); \
                                 continuing toward quorum",
                                meta.round
                            );
                            srv.stat(|st| st.churned += 1);
                        }
                    } else {
                        ensure!(
                            !awaited,
                            "client {id} disconnected mid-round (round {}) with its work \
                             outstanding; the server keeps serving the remaining connections",
                            meta.round
                        );
                    }
                }
            }
        }
        if until == Until::Opportunistic {
            return Ok(());
        }
    }
}

/// Poll-slot tag base for in-progress reconnect handshakes (slot
/// `usize::MAX` is the listener; fleet slots are plain client ids).
const PEND_BASE: usize = usize::MAX - (1 << 20);

/// Cap on simultaneously tracked reconnect handshakes — enough for any
/// realistic crash-restart storm, small enough that a dial flood
/// cannot balloon the poll set.
const PEND_CAP: usize = 64;

/// Drain the accept queue mid-run. Without a quorum the fleet is
/// closed: late connections are churn, shed without touching the
/// round. With one, each accept becomes a pending reconnect handshake
/// polled alongside the fleet (up to [`PEND_CAP`]).
fn accept_churn(srv: &NetServer, pending: &mut Vec<Option<Pending>>, quorum: bool) -> Result<()> {
    while let Some(s) = srv.listener.accept_nonblocking()? {
        if quorum && pending.iter().flatten().count() < PEND_CAP {
            s.set_nonblocking(true)?;
            s.set_nodelay();
            pending.push(Some(Pending {
                stream: s,
                rbuf: RecvBuf::default(),
                deadline: Instant::now() + srv.timeout,
            }));
        } else {
            drop(s);
            srv.stat(|st| st.rejected += 1);
        }
    }
    Ok(())
}

/// One read of a connection's socket through its chaos layer when one
/// is installed, counting injected faults; bytes run straight through
/// otherwise.
fn chaos_fill(srv: &NetServer, c: &mut EvConn) -> io::Result<usize> {
    match c.chaos.as_mut() {
        Some(ch) => {
            let (r, f) = ch.fill(&mut c.stream, &mut c.rbuf);
            if f > 0 {
                srv.stat(|st| st.faults_injected += f);
            }
            r
        }
        None => c.rbuf.fill(&mut c.stream),
    }
}

/// One readiness lap's progress on a mid-run reconnect handshake
/// (quorum mode only). Unlike the accept phase, nothing here aborts
/// the run: a malformed, mismatched or duplicate re-HELLO costs the
/// dialer its connection — never the fleet its round. A valid re-HELLO
/// for a dead slot parks in `rejoins` until the next round boundary
/// (sync) or dispatch lap (async) installs it, with a bumped
/// generation so the chaos layer draws fresh fault streams.
fn reconnect_step(
    srv: &NetServer,
    slot: &mut Option<Pending>,
    conns: &[EvConn],
    rejoins: &mut Vec<(usize, EvConn)>,
    gens: &mut [u64],
    dim: usize,
) {
    let Some(p) = slot.as_mut() else { return };
    let n = conns.len();
    let mut open = true;
    loop {
        match p.rbuf.fill(&mut p.stream) {
            Ok(0) => {
                open = false;
                break;
            }
            Ok(nb) => {
                p.deadline = Instant::now() + srv.timeout;
                srv.stat(|st| st.bytes_in += nb as u64);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => {
                open = false;
                break;
            }
        }
    }
    let admit = match peek_frame(p.rbuf.data()) {
        Err(_) => None,
        Ok(None) if open => return, // frame incomplete; keep waiting
        Ok(None) => None,           // hung up mid-HELLO
        Ok(Some((kind, flen))) => {
            let parsed = (|| {
                if kind != KIND_HELLO {
                    return None;
                }
                let mut cur = Cur::new(&p.rbuf.data()[5..flen]);
                let id = cur.u32().ok()? as usize;
                let fleet = cur.u32().ok()? as usize;
                let hdim = cur.u32().ok()? as usize;
                cur.done().ok()?;
                (id < n && fleet == n && hdim == dim).then_some(id)
            })();
            match parsed {
                Some(id) if conns[id].open => {
                    eprintln!(
                        "[fedeff] rejecting duplicate HELLO from client {id}: its original \
                         connection is still live"
                    );
                    None
                }
                Some(id) => Some((id, flen)),
                None => None,
            }
        }
    };
    match admit {
        None => {
            *slot = None;
            srv.stat(|st| st.rejected += 1);
        }
        Some((id, flen)) => {
            let mut q = slot.take().expect("pending present");
            q.rbuf.consume(flen);
            gens[id] += 1;
            let conn = EvConn {
                stream: q.stream,
                rbuf: q.rbuf,
                scale: [0u8; 4],
                out: VecDeque::new(),
                deadline: q.deadline,
                open: true,
                chaos: srv.chaos.map(|sp| ChaosConn::new(sp, id, gens[id])),
            };
            // latest dial wins if the same id re-HELLOs twice before
            // its slot is recycled
            rejoins.retain(|(r, _)| *r != id);
            rejoins.push((id, conn));
        }
    }
}

/// Drain a connection's queued broadcast frames, front-first and in
/// order, as far as the kernel will take them right now. A ROUND goes
/// out as a 3-segment vectored write — its frame before the scale
/// hole, this client's 4 scale bytes, the frame after — so per-client
/// cost is 4 bytes of state, not a frame copy. A frame that finishes
/// pops and the next queued one (a pipelined round's broadcast, or the
/// shutdown DONE behind it) starts immediately. `lenient` (quorum
/// mode) turns a dead peer into counted churn instead of a run-fatal
/// error — the commit decides whether enough of the fleet survived.
fn drain_conn_out(
    srv: &NetServer,
    c: &mut EvConn,
    id: usize,
    frames: &[Vec<u8>],
    scale_off: usize,
    lenient: bool,
) -> Result<()> {
    let EvConn { stream, scale, out, deadline, open, chaos, .. } = c;
    loop {
        let (frame, sent_now) = match out.front() {
            None => return Ok(()),
            Some(Outgoing::Frame { idx, sent }) => (Some(&frames[*idx]), *sent),
            Some(Outgoing::Done { sent }) => (None, *sent),
        };
        let round_parts: [&[u8]; 3] = match frame {
            Some(f) => [&f[..scale_off], &scale[..], &f[scale_off + 4..]],
            None => [&DONE_FRAME, &[], &[]],
        };
        debug_assert!(
            frame.is_none_or(|f| round_parts.iter().map(|p| p.len()).sum::<usize>() == f.len()),
            "scale splice must preserve the frame length"
        );
        let total: usize = round_parts.iter().map(|p| p.len()).sum();
        let mut iov = [IoSlice::new(&[]); 3];
        let mut niov = 0usize;
        let mut off = sent_now;
        for p in &round_parts {
            if off >= p.len() {
                off -= p.len();
                continue;
            }
            iov[niov] = IoSlice::new(&p[off..]);
            niov += 1;
            off = 0;
        }
        let r = match chaos.as_mut() {
            Some(ch) => {
                let (r, f) = ch.write_vectored(stream, &iov[..niov]);
                if f > 0 {
                    srv.stat(|st| st.faults_injected += f);
                }
                r
            }
            None => stream.write_vectored(&iov[..niov]),
        };
        let wrote = match r {
            Ok(0) => {
                *open = false;
                if lenient {
                    stream.shutdown();
                    eprintln!(
                        "[fedeff] client {id} closed its socket mid-broadcast; continuing \
                         toward quorum"
                    );
                    srv.stat(|st| {
                        st.churned += 1;
                        st.connected = st.connected.saturating_sub(1);
                    });
                    return Ok(());
                }
                bail!("client {id} closed its socket mid-broadcast");
            }
            Ok(n) => n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(()),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                *open = false;
                if lenient {
                    stream.shutdown();
                    eprintln!(
                        "[fedeff] client {id} broadcast write failed ({e}); continuing \
                         toward quorum"
                    );
                    srv.stat(|st| {
                        st.churned += 1;
                        st.connected = st.connected.saturating_sub(1);
                    });
                    return Ok(());
                }
                bail!("client {id} broadcast write failed: {e}");
            }
        };
        srv.stat(|st| st.bytes_out += wrote as u64);
        *deadline = Instant::now() + srv.timeout;
        let new_sent = sent_now + wrote;
        if new_sent >= total {
            out.pop_front();
        } else {
            match out.front_mut() {
                Some(Outgoing::Frame { sent, .. }) | Some(Outgoing::Done { sent }) => {
                    *sent = new_sent;
                }
                None => unreachable!("front frame vanished mid-drain"),
            }
        }
    }
}

/// Decode every complete MSG frame buffered on one connection into its
/// staging slot — the arrival-order half of the deterministic merge.
/// The bit-packed body is borrowed straight out of the receive window
/// (no per-frame copy) and validated against the round context: round
/// echo, channel range, negotiated layout, and the exact byte length
/// the server-side bit formula dictates. Frames for an *earlier* round
/// (a straggler racing a pipelined broadcast) and every frame arriving
/// during the shutdown drain are consumed and discarded loudly —
/// counted in [`ServeStats::stale_discarded`], never decoded; a frame
/// claiming a *future* round stays a hard protocol error.
#[allow(clippy::too_many_arguments)]
fn parse_msg_frames(
    srv: &NetServer,
    c: &mut EvConn,
    id: usize,
    staging: &mut StagedUplink,
    meta: RoundMeta,
    sup: &[u32],
    dim: usize,
    draining: bool,
) -> Result<()> {
    loop {
        let (flen, staged) = {
            let data = c.rbuf.data();
            let Some((kind, flen)) =
                peek_frame(data).with_context(|| format!("framing bytes from client {id}"))?
            else {
                return Ok(());
            };
            ensure!(kind == KIND_MSG, "client {id} sent frame kind {kind}, expected MSG");
            let payload = &data[5..flen];
            let mut cur = Cur::new(payload);
            let mround = cur.u32()? as usize;
            let mch = cur.u8()? as usize;
            let mlayout = cur.u8()?;
            let k = cur.u32()? as usize;
            let body = cur.rest();
            if draining || mround < meta.round {
                eprintln!(
                    "[fedeff] discarding stale MSG from client {id}: round {mround}, ch {mch} \
                     (server {})",
                    if draining {
                        "is draining for shutdown".to_string()
                    } else {
                        format!("is on round {}", meta.round)
                    }
                );
                (flen, false)
            } else {
                let pos = staging
                    .cohort_pos(id)
                    .with_context(|| format!("client {id} sent an MSG outside its cohort round"))?;
                ensure!(
                    mround == meta.round && mch < staging.channels() && mlayout == meta.layout,
                    "client {id} answered (round {mround}, ch {mch}, layout {mlayout}); expected \
                     (round {}, {} channels, layout {})",
                    meta.round,
                    staging.channels(),
                    meta.layout
                );
                staging
                    .stage_with(pos, mch, &mut |sv| {
                        codec::decode_wire_body(mlayout, k, body, dim, sup, sv)
                    })
                    .with_context(|| format!("decoding client {id} channel {mch}"))?;
                (flen, true)
            }
        };
        c.rbuf.consume(flen);
        srv.stat(|st| {
            if staged {
                st.frames_in += 1;
            } else {
                st.stale_discarded += 1;
            }
        });
    }
}

/// A pre-HELLO connection: accepted, polled, not yet part of the fleet.
struct Pending {
    stream: Stream,
    rbuf: RecvBuf,
    deadline: Instant,
}

/// What one readiness lap decided about a pending connection.
enum HelloStep {
    /// Frame still incomplete; keep waiting.
    Wait,
    /// Peer hung up before completing HELLO; quiet churn drop.
    Dead,
    /// Valid HELLO: join the fleet as `id`, consuming `flen` bytes
    /// (any extra buffered bytes ride along into the event loop).
    Join { id: usize, flen: usize },
}

/// A bound coordinator endpoint. [`NetServer::bind`] first (so tests
/// and scripts can read the real port before starting a fleet), then
/// [`NetServer::serve`] a spec against it.
pub struct NetServer {
    listener: Listener,
    /// Per-connection progress deadline (reads, writes, and the
    /// pre-HELLO idle eviction all refresh against it).
    pub timeout: Duration,
    /// Cap on concurrently tracked connections; extras are accepted
    /// and immediately shed. `None` = uncapped.
    pub max_clients: Option<usize>,
    /// Quorum fraction for sync rounds / async fleet floor
    /// (`[faults] quorum`, `--quorum`). `None` keeps every mid-round
    /// loss a hard, named error; `Some(q)` commits a round once
    /// `ceil(q × cohort)` members delivered, evicting the rest on
    /// their own deadlines, and re-admits reconnecting clients.
    pub quorum: Option<f64>,
    /// Deterministic fault injection wrapped around every accepted
    /// connection's I/O ([`ChaosSpec`]); `None` runs bytes untouched.
    pub chaos: Option<ChaosSpec>,
    stats: RefCell<ServeStats>,
}

impl NetServer {
    pub fn bind(addr: &str) -> Result<NetServer> {
        Ok(NetServer {
            listener: Listener::bind(addr)?,
            timeout: DEFAULT_TIMEOUT,
            max_clients: None,
            quorum: None,
            chaos: None,
            stats: RefCell::new(ServeStats::default()),
        })
    }

    /// The canonical connect address (resolves `tcp:...:0`).
    pub fn local_addr(&self) -> Result<String> {
        self.listener.local_addr()
    }

    /// Snapshot of the live serve counters.
    pub fn stats(&self) -> ServeStats {
        self.stats.borrow().clone()
    }

    fn stat(&self, f: impl FnOnce(&mut ServeStats)) {
        f(&mut self.stats.borrow_mut());
    }

    /// Accept HELLO handshakes until all `n` client slots are filled,
    /// multiplexing every pending connection: a peer may trickle its
    /// HELLO byte by byte, a silent peer is evicted on its own idle
    /// deadline without delaying anyone, and a malformed or duplicate
    /// HELLO aborts the serve — the coordinator refuses to run a round
    /// over a broken fleet. The whole accept phase also carries a
    /// global no-progress deadline so a fleet that never completes
    /// errors out instead of hanging.
    fn accept_fleet(&self, n: usize, dim: usize, has_comp: bool) -> Result<NetTransport<'_>> {
        let cap = self.max_clients.unwrap_or(usize::MAX);
        ensure!(cap >= n, "--max-clients {cap} cannot host a fleet of {n}");
        if let Some(q) = self.quorum {
            ensure!(q.is_finite() && q > 0.0 && q <= 1.0, "quorum must be in (0, 1], got {q}");
        }
        self.listener.set_nonblocking(true)?;
        let mut slots: Vec<Option<(Stream, RecvBuf)>> = Vec::new();
        slots.resize_with(n, || None);
        let mut pending: Vec<Option<Pending>> = Vec::new();
        let mut poller = evloop::Poller::new();
        let mut joined = 0usize;
        let mut last_progress = Instant::now();
        while joined < n {
            let now = Instant::now();
            ensure!(
                now < last_progress + self.timeout,
                "timed out waiting for the fleet: {joined}/{n} clients joined within {:?}",
                self.timeout
            );
            // evict pre-HELLO connections that sat silent past their
            // own deadline — they never delay the fleet
            for p in pending.iter_mut() {
                if p.as_ref().is_some_and(|q| now >= q.deadline) {
                    *p = None;
                    self.stat(|s| s.evicted += 1);
                }
            }
            pending.retain(|p| p.is_some());

            poller.clear();
            poller.push(self.listener.raw_fd(), evloop::Interest { read: true, write: false });
            let mut wake = last_progress + self.timeout;
            for p in pending.iter().flatten() {
                poller.push(p.stream.raw_fd(), evloop::Interest { read: true, write: false });
                wake = wake.min(p.deadline);
            }
            let registered = pending.len();
            poller.wait(wake.saturating_duration_since(now))?;

            if poller.readiness(0).readable {
                while let Some(s) = self.listener.accept_nonblocking()? {
                    if joined + pending.len() >= cap {
                        drop(s);
                        self.stat(|st| st.rejected += 1);
                        continue;
                    }
                    s.set_nonblocking(true)?;
                    s.set_nodelay();
                    pending.push(Some(Pending {
                        stream: s,
                        rbuf: RecvBuf::default(),
                        deadline: Instant::now() + self.timeout,
                    }));
                }
            }

            for i in 0..registered {
                let rd = poller.readiness(1 + i);
                if !(rd.readable || rd.closed) {
                    continue;
                }
                let step = {
                    let Some(p) = pending[i].as_mut() else { continue };
                    let mut open = true;
                    loop {
                        match p.rbuf.fill(&mut p.stream) {
                            Ok(0) => {
                                open = false;
                                break;
                            }
                            Ok(nb) => {
                                p.deadline = Instant::now() + self.timeout;
                                self.stat(|st| st.bytes_in += nb as u64);
                            }
                            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                            Err(e) => {
                                let _ = e;
                                open = false;
                                break;
                            }
                        }
                    }
                    match peek_frame(p.rbuf.data()).context("reading HELLO")? {
                        Some((kind, flen)) => {
                            ensure!(
                                kind == KIND_HELLO,
                                "first frame must be HELLO, got kind {kind}"
                            );
                            let mut cur = Cur::new(&p.rbuf.data()[5..flen]);
                            let id = cur.u32()? as usize;
                            let fleet = cur.u32()? as usize;
                            let hdim = cur.u32()? as usize;
                            cur.done().context("reading HELLO")?;
                            ensure!(
                                fleet == n,
                                "client expects a fleet of {fleet}, server runs {n}"
                            );
                            ensure!(hdim == dim, "client expects dim {hdim}, server runs {dim}");
                            ensure!(id < n, "client id {id} out of range for a fleet of {n}");
                            ensure!(slots[id].is_none(), "client id {id} joined twice");
                            HelloStep::Join { id, flen }
                        }
                        None if !open => HelloStep::Dead,
                        None => HelloStep::Wait,
                    }
                };
                match step {
                    HelloStep::Wait => {}
                    HelloStep::Dead => {
                        pending[i] = None;
                        self.stat(|st| st.churned += 1);
                    }
                    HelloStep::Join { id, flen } => {
                        let mut q = pending[i].take().expect("pending present");
                        q.rbuf.consume(flen);
                        slots[id] = Some((q.stream, q.rbuf));
                        joined += 1;
                        last_progress = Instant::now();
                        self.stat(|st| st.connected += 1);
                    }
                }
            }
            pending.retain(|p| p.is_some());
        }
        // connections beyond the completed fleet are shed
        self.stat(|st| st.rejected += pending.iter().flatten().count() as u64);
        drop(pending);

        let now = Instant::now();
        let conns: Vec<EvConn> = slots
            .into_iter()
            .enumerate()
            .map(|(id, s)| {
                let (stream, rbuf) = s.expect("all slots filled");
                EvConn {
                    stream,
                    rbuf,
                    scale: [0u8; 4],
                    out: VecDeque::new(),
                    deadline: now + self.timeout,
                    open: true,
                    chaos: self.chaos.map(|sp| ChaosConn::new(sp, id, 0)),
                }
            })
            .collect();
        Ok(NetTransport {
            srv: self,
            dim,
            has_comp,
            inner: RefCell::new(TransportInner {
                conns,
                staging: StagedUplink::default(),
                poller: evloop::Poller::new(),
                pslots: Vec::new(),
                frames: Vec::new(),
                wbuf: BitWriter::new(),
                scale_off: 0,
                round: 0,
                layout: LAYOUT_SPARSE,
                draining: false,
                sup: Vec::new(),
                input: PoolInput::default(),
                pending: Vec::new(),
                rejoins: Vec::new(),
                casualties: Vec::new(),
                gens: vec![0; n],
            }),
        })
    }

    /// Drive a full networked run of `spec`: accept one connection per
    /// dataset client, stream every round over the sockets through the
    /// event loop, broadcast DONE, and return the record — bit-for-bit
    /// the in-process fused run of the same spec. `on_eval` fires at
    /// every eval round (the JSON metrics line of `fedeff serve
    /// --listen`).
    pub fn serve(&self, spec: &Spec, on_eval: &mut dyn FnMut(&RoundStat)) -> Result<RunRecord> {
        if let Some(sc) = &spec.scenario {
            let scen = build_scenario(sc)?;
            return match scen.mode {
                Mode::BufferedAsync { buffer, staleness } => {
                    self.serve_buffered_async(spec, &scen, buffer, staleness, on_eval)
                }
                Mode::Sync => bail!(
                    "sync-mode time-aware scenarios are in-process only (the virtual clock \
                     replaces the real barrier); use mode = \"async\", drop [scenario], or \
                     serve without --listen"
                ),
            };
        }
        let oracle = fleet_oracle(spec)?;
        let n = spec.dataset.clients;
        let d = oracle.dim();
        let mut alg = build_algorithm(&spec.algorithm, &oracle)?;
        if self.quorum.is_some() {
            // a MeanOverCohort scale divides by the cohort size the
            // dispatch assumed — losing a member mid-round would
            // silently re-weight every survivor. Weighted-HT scales
            // are per-client and lose exactly the lost member's term.
            ensure!(
                alg.uplink_plan()
                    .is_some_and(|p| matches!(p.scale, ScaleSpec::WeightedHt { .. })),
                "[faults] quorum needs a cohort-size-independent uplink scale (weighted-HT): \
                 {} would re-weight the survivors when a cohort member is lost mid-round",
                alg.label()
            );
        }
        let driver = build_driver(spec, n)?;
        let transport = self.accept_fleet(n, d, leaf_compressor(spec).is_some())?;
        let x0 = vec![0.5f32; d];
        let mut cb = |r: &RoundStat| on_eval(r);
        let rec = driver.run_with_transport(
            alg.as_mut(),
            &oracle,
            &transport,
            &x0,
            &spec_opts(spec),
            Some(&mut cb),
        )?;
        transport.shutdown()?;
        Ok(rec)
    }

    /// The event-loop analog of [`crate::scenario::run_buffered_async`]
    /// over real sockets: every client flies continuously at its own
    /// pace, computing each payload against the anchor its ROUND frame
    /// carried (dense or delta) on dispatch-counter-keyed RNG streams;
    /// the server folds a staleness-weighted aggregate every `buffer`
    /// arrivals and re-broadcasts the new anchor per client. The fold
    /// sequence is decided by **virtual** arrival time — dispatch vtime
    /// + drawn compute + bits/bandwidth — never by socket arrival
    /// order, and uplink bits are booked when a client's MSG lands,
    /// which the engine serializes before the next fold so every
    /// ledger snapshot sees exactly the totals the in-process engine
    /// books at dispatch time. Bit-for-bit the in-process run: losses,
    /// booked bits, dispatch/apply counters (pinned by
    /// rust/tests/serve_net.rs).
    fn serve_buffered_async(
        &self,
        spec: &Spec,
        sspec: &ScenarioSpec,
        buffer: usize,
        staleness: Staleness,
        on_eval: &mut dyn FnMut(&RoundStat),
    ) -> Result<RunRecord> {
        let oracle = fleet_oracle(spec)?;
        let n = spec.dataset.clients;
        let d = oracle.dim();
        let mut alg: Box<dyn FlAlgorithm> = build_algorithm(&spec.algorithm, &oracle)?;
        let drv = build_driver(spec, n)?;
        let opts = spec_opts(spec);
        // the in-process engine's contract, verbatim — plus the wire's
        // own requirement of a sparse-codable uplink
        ensure!(
            matches!(drv.topology, Topology::Flat),
            "buffered-async scenarios support only the flat topology"
        );
        ensure!(
            drv.mask.is_none(),
            "buffered-async scenarios do not compose with training-time sparsity masks"
        );
        ensure!(
            drv.sampler.is_none(),
            "buffered-async scenarios run every client continuously; drop the cohort sampler"
        );
        ensure!(
            alg.supports_async(),
            "{} does not support buffered-async aggregation",
            alg.label()
        );
        ensure!((1..=n).contains(&buffer), "async buffer size must be in 1..={n}, got {buffer}");
        let comp = leaf_compressor(spec);
        ensure!(
            comp.is_some(),
            "a networked buffered-async serve needs a sparse-capable uplink compressor (the \
             wire carries codec frames, not dense payloads)"
        );
        let x0 = vec![0.5f32; d];
        alg.init(&oracle, &x0, &opts)?;
        let (payload, weights) = {
            let plan = match alg.uplink_plan() {
                Some(p) if p.executable() && p.channels() == 1 => p,
                _ => bail!(
                    "{} advertises no single-channel executable uplink plan for async execution",
                    alg.label()
                ),
            };
            let payload = match plan.payload {
                PayloadSpec::Gradient => FusedPayload::Gradient,
                PayloadSpec::LocalSgd { steps, lr, prox_mu } => {
                    FusedPayload::LocalSgd { steps, lr, prox_mu }
                }
                _ => bail!(
                    "{} advertises no single-channel executable uplink plan for async execution",
                    alg.label()
                ),
            };
            let weights = match plan.scale {
                ScaleSpec::MeanOverCohort => None,
                ScaleSpec::WeightedHt { weights } => Some(weights.to_vec()),
            };
            (payload, weights)
        };
        let mut tracker = match drv.down_mode {
            DownlinkMode::Dense => None,
            DownlinkMode::Delta => {
                ensure!(
                    drv.down.is_none(),
                    "the anchor-delta downlink replaces the downlink compressor; configure one \
                     or the other"
                );
                Some(DeltaTracker::new(&alg.eval_point(), n))
            }
        };
        let speeds: Vec<f64> = (0..n)
            .map(|c| sspec.speed.sample(&mut event_rng(opts.seed, 0, c, EV_SPEED)))
            .collect();

        let transport = self.accept_fleet(n, d, comp.is_some())?;
        let mut guard = transport.inner.borrow_mut();
        let inner = &mut *guard;
        inner.frames.resize_with(n, Vec::new);
        inner.layout = LAYOUT_SPARSE;
        let mut st = AsyncNetState {
            speeds,
            k: vec![0; n],
            base_t: vec![0.0; n],
            arrival: vec![0.0; n],
            known: vec![false; n],
            dropflag: vec![false; n],
            anchor_ver: vec![0; n],
            recv: vec![0.0; n * d],
            sv: SparseVec::default(),
            dplan: DeltaRound::default(),
            dispatches: 0,
            dropped: 0,
            lost: 0,
        };
        let mut version = 0u64;
        let mut ledger = CommLedger::default();
        let mut rec = RunRecord::new(alg.label());
        record_eval(alg.as_ref(), &oracle, 0, &ledger, &opts, 0.0, &mut rec)?;
        on_eval(rec.rounds.last().expect("eval just recorded"));
        let bw = sspec.bandwidth;
        {
            let anchor = alg.eval_point();
            for c in 0..n {
                async_dispatch(
                    self, inner, &mut st, &mut ledger, &mut tracker, &anchor, payload, sspec,
                    opts.seed, d, version, c, 0.0,
                )?;
            }
        }
        let mut agg = vec![0.0f32; d];
        let mut in_buffer = 0usize;
        let mut applies = 0usize;
        let mut vtime = 0.0f64;
        while applies < opts.rounds {
            // every in-flight MSG must land before the argmin: booking
            // its uplink bits here (instead of at dispatch, where the
            // in-process engine predicts them) is what keeps each
            // snapshot's totals identical
            pump_async(self, inner, &mut st, &mut ledger, d, bw)?;
            // install completed re-HELLOs into their dead slots: forget
            // the replica (next downlink resyncs dense) and, when the
            // slot's flight already parked at infinity, redispatch at
            // the current virtual time so the client rejoins the race
            for (id, mut conn) in std::mem::take(&mut inner.rejoins) {
                conn.deadline = Instant::now() + self.timeout;
                inner.conns[id] = conn;
                self.stat(|s| {
                    s.connected += 1;
                    s.reconnects += 1;
                    s.resyncs += 1;
                });
                st.lost = st.lost.saturating_sub(1);
                if let Some(tr) = tracker.as_mut() {
                    tr.forget(id);
                }
                if st.arrival[id].is_infinite() {
                    let anchor = alg.eval_point();
                    async_dispatch(
                        self, inner, &mut st, &mut ledger, &mut tracker, &anchor, payload,
                        sspec, opts.seed, d, version, id, vtime,
                    )?;
                    // the fresh flight's arrival must be known before
                    // the argmin — the fold order follows the virtual
                    // clock, never the socket clock
                    pump_async(self, inner, &mut st, &mut ledger, d, bw)?;
                }
            }
            // next arrival: earliest in-flight update, client-id tiebreak
            let mut c = 0usize;
            for i in 1..n {
                if st.arrival[i] < st.arrival[c] {
                    c = i;
                }
            }
            let now = st.arrival[c];
            vtime = now;
            if !st.dropflag[c] {
                let s = version - st.anchor_ver[c];
                let wc = weights.as_ref().map_or(1.0, |w| w[c] as f64);
                let coeff = (staleness.weight(s) * wc / buffer as f64) as f32;
                vm::axpy(coeff, &st.recv[c * d..(c + 1) * d], &mut agg);
                in_buffer += 1;
                if in_buffer == buffer {
                    alg.absorb_async(&agg)?;
                    agg.fill(0.0);
                    in_buffer = 0;
                    version += 1;
                    if let Some(tr) = tracker.as_mut() {
                        tr.record_round(&alg.eval_point());
                    }
                    applies += 1;
                    ledger.charge(drv.topology.round_cost(1));
                    ledger.snapshot(applies - 1);
                    if applies < opts.rounds && applies % opts.eval_every == 0 {
                        record_eval(alg.as_ref(), &oracle, applies, &ledger, &opts, vtime, &mut rec)?;
                        on_eval(rec.rounds.last().expect("eval just recorded"));
                    }
                }
            }
            if applies < opts.rounds {
                let anchor = alg.eval_point();
                async_dispatch(
                    self, inner, &mut st, &mut ledger, &mut tracker, &anchor, payload, sspec,
                    opts.seed, d, version, c, now,
                )?;
            }
        }
        record_eval(alg.as_ref(), &oracle, opts.rounds, &ledger, &opts, vtime, &mut rec)?;
        on_eval(rec.rounds.last().expect("eval just recorded"));
        rec.scenario = Some(ScenarioStat {
            vtime,
            dropped: st.dropped,
            unavailable: 0,
            dispatches: st.dispatches,
            applies: applies as u64,
        });
        drop(guard);
        transport.shutdown()?;
        Ok(rec)
    }
}

/// Per-client flight state of the *networked* buffered-async engine —
/// the wire analog of the in-process `AsyncState`: same counters, same
/// RNG keying, but the payload is computed by the real remote client
/// and the uplink bits are read off the decoded MSG instead of
/// predicted at dispatch.
struct AsyncNetState {
    /// Per-client persistent speed factor, drawn once per run.
    speeds: Vec<f64>,
    /// Per-client dispatch counter — the "round" echoed in its frames,
    /// so redispatches draw fresh, deterministic randomness.
    k: Vec<usize>,
    /// Dispatch vtime + drawn compute; the virtual arrival becomes
    /// `base_t + bits / bandwidth` once the MSG lands — the exact
    /// association order of the in-process engine's sum.
    base_t: Vec<f64>,
    /// Virtual arrival time of each client's in-flight update (valid
    /// only where `known`).
    arrival: Vec<f64>,
    /// Whether the in-flight update's MSG has landed.
    known: Vec<bool>,
    /// Whether the in-flight update drops on arrival (drawn at
    /// dispatch; a dropped update still travels, its bits just go
    /// unbooked — the ledger sees only bits the fold accepts).
    dropflag: Vec<bool>,
    /// Server version each in-flight update anchored on.
    anchor_ver: Vec<u64>,
    /// Decoded payloads, `n * d` flattened (zeroed + scattered per
    /// MSG — the dense image the in-process compressor writes).
    recv: Vec<f32>,
    /// MSG decode scratch.
    sv: SparseVec,
    /// Per-dispatch delta-plan scratch ([`DownlinkMode::Delta`]).
    dplan: DeltaRound,
    dispatches: u64,
    dropped: u64,
    /// Clients currently disconnected (quorum mode): the fleet-floor
    /// count — incremented on eviction/hangup, decremented on rejoin.
    lost: usize,
}

/// Dispatch client `c` at virtual time `now`: draw its compute time
/// and dropout coin from the same [`event_rng`] streams as the
/// in-process engine, book the downlink (dense anchor, or the
/// per-client min(dense resync, delta) plan), encode its personal
/// ROUND frame — round = its dispatch counter, so the remote
/// compressor forks the right `client_rng` stream — and queue it on
/// its connection. Uplink bits are booked when the MSG arrives
/// ([`parse_async_msgs`]).
#[allow(clippy::too_many_arguments)]
fn async_dispatch(
    srv: &NetServer,
    inner: &mut TransportInner,
    st: &mut AsyncNetState,
    ledger: &mut CommLedger,
    tracker: &mut Option<DeltaTracker>,
    anchor: &[f32],
    payload: FusedPayload,
    sspec: &ScenarioSpec,
    seed: u64,
    dim: usize,
    version: u64,
    c: usize,
    now: f64,
) -> Result<()> {
    let kc = st.k[c];
    st.k[c] += 1;
    let compute = st.speeds[c] * sspec.compute.sample(&mut event_rng(seed, kc, c, EV_COMPUTE));
    let dropped = sspec.drop > 0.0 && event_rng(seed, kc, c, EV_DROP).bernoulli(sspec.drop);
    st.base_t[c] = now + compute;
    st.known[c] = false;
    st.dropflag[c] = dropped;
    st.anchor_ver[c] = version;
    st.dispatches += 1;
    if dropped {
        st.dropped += 1;
    }
    inner.input.round = kc;
    inner.input.seed = seed;
    inner.input.payload = payload;
    inner.input.sup.clear();
    inner.input.point.clear();
    inner.input.point.extend_from_slice(anchor);
    let scale_off = match tracker.as_mut() {
        Some(tr) => {
            let cc = [c];
            tr.plan(&cc, &mut st.dplan);
            ledger.down(st.dplan.total_bits(), 1);
            tr.ack(&cc);
            encode_round_frame(
                &mut inner.frames[c],
                &inner.input,
                LAYOUT_SPARSE,
                dim,
                Some((&st.dplan, st.dplan.assign[0] as usize)),
                &mut inner.wbuf,
            )?
        }
        None => {
            ledger.down(dense_bits(dim), 1);
            encode_round_frame(
                &mut inner.frames[c],
                &inner.input,
                LAYOUT_SPARSE,
                dim,
                None,
                &mut inner.wbuf,
            )?
        }
    };
    inner.scale_off = scale_off;
    let conn = inner
        .conns
        .get_mut(c)
        .with_context(|| format!("async client {c} has no connection"))?;
    if !conn.open {
        ensure!(
            srv.quorum.is_some(),
            "client {c} disconnected in an earlier dispatch; cannot redispatch (dispatch {kc})"
        );
        // a departed client's dispatch books exactly like the
        // in-process engine's scripted departure: downlink planned,
        // booked and acked above, the uplink never arrives, and the
        // flight slot parks at infinity so the argmin skips it
        st.known[c] = true;
        st.arrival[c] = f64::INFINITY;
        if !dropped {
            st.dropped += 1;
        }
        return Ok(());
    }
    // async folds scale per arrival (staleness * weight / buffer); the
    // frame's spliced scale is the identity
    conn.scale = 1.0f32.to_le_bytes();
    conn.out.push_back(Outgoing::Frame { idx: c, sent: 0 });
    conn.deadline = Instant::now() + srv.timeout;
    let qd = conn.out.len() as u64;
    srv.stat(|s| {
        s.rounds_broadcast += 1;
        s.max_queue_depth = s.max_queue_depth.max(qd);
    });
    Ok(())
}

/// Event-loop laps for the buffered-async serve: drain queued
/// per-client ROUND frames and read MSGs until every in-flight
/// update's virtual arrival is known — the barrier the fold argmin
/// needs. Socket arrival order only decides when decode work happens;
/// the virtual clock decides the folds. Deadlines are per connection,
/// enforced only for clients the barrier is actually waiting on.
fn pump_async(
    srv: &NetServer,
    inner: &mut TransportInner,
    st: &mut AsyncNetState,
    ledger: &mut CommLedger,
    dim: usize,
    bw: f64,
) -> Result<()> {
    let TransportInner { conns, poller, pslots, frames, scale_off, pending, rejoins, gens, .. } =
        inner;
    let scale_off = *scale_off;
    let quorum = srv.quorum;
    loop {
        if st.known.iter().all(|&b| b) {
            return Ok(());
        }

        let now = Instant::now();
        let mut next_deadline: Option<Instant> = None;
        for (id, c) in conns.iter_mut().enumerate() {
            if !c.open {
                continue;
            }
            let awaited = !st.known[id] || !c.out.is_empty();
            if !awaited {
                continue;
            }
            if now >= c.deadline {
                let Some(q) = quorum else {
                    bail!(
                        "client {id} stalled: no socket progress within {:?} (dispatch {}); \
                         evicting it and aborting the run",
                        srv.timeout,
                        st.k[id].saturating_sub(1)
                    );
                };
                eprintln!(
                    "[fedeff] evicting client {id}: no socket progress within {:?} \
                     (dispatch {})",
                    srv.timeout,
                    st.k[id].saturating_sub(1)
                );
                c.open = false;
                c.stream.shutdown();
                srv.stat(|stt| {
                    stt.evicted += 1;
                    stt.connected = stt.connected.saturating_sub(1);
                });
                st.lost += 1;
                async_depart(st, id);
                async_floor(st, q, id)?;
                continue;
            }
            next_deadline = Some(next_deadline.map_or(c.deadline, |d| d.min(c.deadline)));
        }
        for p in pending.iter_mut() {
            if p.as_ref().is_some_and(|q| now >= q.deadline) {
                *p = None;
                srv.stat(|stt| stt.evicted += 1);
            }
        }
        pending.retain(|p| p.is_some());
        for p in pending.iter().flatten() {
            next_deadline = Some(next_deadline.map_or(p.deadline, |d| d.min(p.deadline)));
        }

        poller.clear();
        pslots.clear();
        poller.push(srv.listener.raw_fd(), evloop::Interest { read: true, write: false });
        pslots.push(usize::MAX);
        for (id, c) in conns.iter().enumerate() {
            if !c.open {
                continue;
            }
            let interest = evloop::Interest { read: true, write: !c.out.is_empty() };
            poller.push(c.stream.raw_fd(), interest);
            pslots.push(id);
        }
        for (i, p) in pending.iter().enumerate() {
            if let Some(p) = p {
                poller.push(p.stream.raw_fd(), evloop::Interest { read: true, write: false });
                pslots.push(PEND_BASE + i);
            }
        }
        let timeout =
            next_deadline.map_or(Duration::from_millis(100), |d| d.saturating_duration_since(now));
        poller.wait(timeout)?;

        for (slot, &id) in pslots.iter().enumerate() {
            let rd = poller.readiness(slot);
            if !(rd.readable || rd.writable || rd.closed) {
                continue;
            }
            if id == usize::MAX {
                accept_churn(srv, pending, quorum.is_some())?;
                continue;
            }
            if id >= PEND_BASE {
                reconnect_step(srv, &mut pending[id - PEND_BASE], conns, rejoins, gens, dim);
                continue;
            }
            let c = &mut conns[id];
            let was_open = c.open;
            if c.open && !c.out.is_empty() && (rd.writable || rd.closed) {
                drain_conn_out(srv, c, id, frames, scale_off, quorum.is_some())?;
            }
            let closed_by_write = was_open && !c.open;
            if c.open && (rd.readable || rd.closed) {
                loop {
                    match chaos_fill(srv, c) {
                        Ok(0) => {
                            c.open = false;
                            srv.stat(|stt| stt.connected = stt.connected.saturating_sub(1));
                            break;
                        }
                        Ok(n) => {
                            c.deadline = Instant::now() + srv.timeout;
                            srv.stat(|stt| stt.bytes_in += n as u64);
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                        Err(e) => {
                            let _ = e;
                            c.open = false;
                            srv.stat(|stt| stt.connected = stt.connected.saturating_sub(1));
                            break;
                        }
                    }
                }
                parse_async_msgs(srv, c, id, st, ledger, dim, bw)?;
            }
            if was_open && !c.open {
                match quorum {
                    Some(q) => {
                        if !closed_by_write {
                            eprintln!(
                                "[fedeff] client {id} hung up (dispatch {}); continuing \
                                 under the quorum floor",
                                st.k[id].saturating_sub(1)
                            );
                            srv.stat(|stt| stt.churned += 1);
                        }
                        st.lost += 1;
                        async_depart(st, id);
                        async_floor(st, q, id)?;
                    }
                    None => {
                        ensure!(
                            st.known[id] && c.out.is_empty(),
                            "client {id} disconnected with its update in flight (dispatch \
                             {}); a continuous async fleet cannot lose members",
                            st.k[id].saturating_sub(1)
                        );
                    }
                }
            }
        }
    }
}

/// Mark a lost async client's in-flight slot departed — the wire
/// analog of the in-process engine's scripted departure: the arrival
/// parks at infinity (the argmin skips it until a rejoin) and the
/// update counts dropped unless its dispatch already drew the drop.
/// A no-op when the update already landed: a delivered payload still
/// folds even if its sender died afterwards.
fn async_depart(st: &mut AsyncNetState, id: usize) {
    if !st.known[id] {
        st.known[id] = true;
        st.arrival[id] = f64::INFINITY;
        if !st.dropflag[id] {
            st.dropped += 1;
        }
    }
}

/// The async fleet floor: with quorum `q` over `n` continuous clients,
/// losing past `n - ceil(q*n)` members is a run-fatal error naming the
/// last casualty.
fn async_floor(st: &AsyncNetState, q: f64, id: usize) -> Result<()> {
    let n = st.known.len();
    let need = ((q * n as f64).ceil() as usize).max(1);
    ensure!(
        n - st.lost >= need,
        "client {id} lost (dispatch {}): only {}/{n} async clients remain (quorum {q} needs \
         {need})",
        st.k[id].saturating_sub(1),
        n - st.lost
    );
    Ok(())
}

/// Decode every complete MSG buffered on one async connection: validate
/// the dispatch-counter echo, single channel, sparse layout and exact
/// body length, scatter the payload into the client's dense receive
/// slot, fix its virtual arrival (`base_t + bits / bandwidth`), and
/// book the uplink bits unless the update was drawn as dropped. A
/// duplicate or out-of-order MSG is a hard protocol error — an async
/// client has exactly one update in flight by construction.
fn parse_async_msgs(
    srv: &NetServer,
    c: &mut EvConn,
    id: usize,
    st: &mut AsyncNetState,
    ledger: &mut CommLedger,
    dim: usize,
    bw: f64,
) -> Result<()> {
    loop {
        let flen = {
            let data = c.rbuf.data();
            let Some((kind, flen)) =
                peek_frame(data).with_context(|| format!("framing bytes from client {id}"))?
            else {
                return Ok(());
            };
            ensure!(kind == KIND_MSG, "client {id} sent frame kind {kind}, expected MSG");
            let payload = &data[5..flen];
            let mut cur = Cur::new(payload);
            let mround = cur.u32()? as usize;
            let mch = cur.u8()? as usize;
            let mlayout = cur.u8()?;
            let kpairs = cur.u32()? as usize;
            let body = cur.rest();
            let kc = st.k[id]
                .checked_sub(1)
                .with_context(|| format!("client {id} answered before any dispatch"))?;
            ensure!(!st.known[id], "client {id} sent a duplicate MSG for dispatch {kc}");
            ensure!(
                mround == kc && mch == 0 && mlayout == LAYOUT_SPARSE,
                "client {id} answered (round {mround}, ch {mch}, layout {mlayout}); expected \
                 (round {kc}, 1 channel, layout {LAYOUT_SPARSE})"
            );
            let bits = codec::decode_wire_body(mlayout, kpairs, body, dim, &[], &mut st.sv)
                .with_context(|| format!("decoding client {id} dispatch {kc}"))?;
            let out = &mut st.recv[id * dim..(id + 1) * dim];
            out.fill(0.0);
            for (&i, &v) in st.sv.idx.iter().zip(&st.sv.val) {
                out[i as usize] = v;
            }
            st.arrival[id] = st.base_t[id] + bits as f64 / bw;
            st.known[id] = true;
            if !st.dropflag[id] {
                ledger.up(bits, 1);
            }
            flen
        };
        c.rbuf.consume(flen);
        srv.stat(|s| s.frames_in += 1);
    }
}

// ---------------------------------------------------------------------
// client fleet
// ---------------------------------------------------------------------

/// Run the client side of a networked serve: one simulated client per
/// dataset client (each on its own thread with its own compressor
/// fork), all built from the same spec the server loaded, connecting to
/// `addr` and answering ROUND frames until DONE.
pub fn run_fleet(addr: &str, spec: &Spec) -> Result<()> {
    let ids: Vec<usize> = (0..spec.dataset.clients).collect();
    run_fleet_clients(addr, spec, &ids)
}

/// [`run_fleet`] restricted to a subset of client ids — the missing
/// ids never connect, which is how the adversarial tests stand in for
/// stalled or misbehaving fleet members while the rest of the fleet
/// behaves normally.
pub fn run_fleet_clients(addr: &str, spec: &Spec, clients: &[usize]) -> Result<()> {
    let cp: Vec<(usize, ClientPolicy)> =
        clients.iter().map(|&c| (c, ClientPolicy::default())).collect();
    run_fleet_inner(addr, spec, &cp)
}

/// A full fleet where the scripted clients deliberately drop their
/// connection after fully reading the ROUND/dispatch numbered `r` in
/// each `(client, r)` pair — and never come back. The deaths are
/// clean: the victim's thread returns `Ok`, so the server-side record
/// (quorum skips, eviction/churn counters, the committed losses) is
/// the sole verdict on the run.
pub fn run_fleet_faulty(addr: &str, spec: &Spec, deaths: &[(usize, usize)]) -> Result<()> {
    run_fleet_inner(addr, spec, &death_policies(spec, deaths, false)?)
}

/// [`run_fleet_faulty`] whose victims crash-restart: each scripted
/// client drops its connection after the named round/dispatch, then
/// re-dials with its [`Backoff`] schedule, re-HELLOs with its id, and
/// serves on — the client half of the coordinator's reconnect/resume
/// path (quorum mode only; without `--quorum` the server refuses the
/// re-HELLO and the run dies on the original loss).
pub fn run_fleet_reconnecting(addr: &str, spec: &Spec, deaths: &[(usize, usize)]) -> Result<()> {
    run_fleet_inner(addr, spec, &death_policies(spec, deaths, true)?)
}

fn death_policies(
    spec: &Spec,
    deaths: &[(usize, usize)],
    reconnect: bool,
) -> Result<Vec<(usize, ClientPolicy)>> {
    let n = spec.dataset.clients;
    let mut v: Vec<(usize, ClientPolicy)> =
        (0..n).map(|c| (c, ClientPolicy::default())).collect();
    for &(c, r) in deaths {
        ensure!(c < n, "death script names client {c}, fleet has {n}");
        v[c].1 = ClientPolicy { reconnect, die_at: Some(r) };
    }
    Ok(v)
}

fn run_fleet_inner(addr: &str, spec: &Spec, clients: &[(usize, ClientPolicy)]) -> Result<()> {
    let oracle = fleet_oracle(spec)?;
    let n = spec.dataset.clients;
    let d = oracle.dim();
    let comp = leaf_compressor(spec);
    for &(c, _) in clients {
        ensure!(c < n, "fleet client id {c} out of range for {n} dataset clients");
    }
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(clients.len());
        for &(c, policy) in clients {
            let oracle = &oracle;
            let comp = comp.clone();
            handles.push(
                scope.spawn(move || client_loop(addr, c, n, d, comp.as_ref(), oracle, policy)),
            );
        }
        let mut first_err = None;
        for (h, &(c, _)) in handles.into_iter().zip(clients) {
            let res = h.join().map_err(|_| anyhow::anyhow!("fleet client {c} panicked"));
            if let Err(e) = res.and_then(|r| r) {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })
}

/// Per-client fault script for the simulated fleet.
#[derive(Clone, Copy, Default)]
struct ClientPolicy {
    /// Crash-restart after a scripted death or lost connection instead
    /// of ending the thread / propagating the error.
    reconnect: bool,
    /// Deliberately drop the connection after fully reading the ROUND
    /// whose round/dispatch counter equals this (fires once).
    die_at: Option<usize>,
}

/// Reconnect-cycle cap per client: a coordinator that keeps dying on
/// the same client propagates the last error instead of dialing
/// forever.
const MAX_RECONNECTS: usize = 32;

/// Dial the coordinator (paced by `backoff`) and complete the HELLO —
/// the one connect path shared by a fleet's initial join and every
/// mid-run reconnect.
fn client_connect(
    addr: &str,
    client: usize,
    fleet: usize,
    dim: usize,
    backoff: &mut Backoff,
) -> Result<Conn> {
    let stream = connect_retry(addr, Duration::from_secs(10), backoff)?;
    stream.set_nodelay();
    let mut conn = Conn::new(stream, DEFAULT_TIMEOUT)?;
    let mut hello = Vec::with_capacity(12);
    hello.extend_from_slice(&(client as u32).to_le_bytes());
    hello.extend_from_slice(&(fleet as u32).to_le_bytes());
    hello.extend_from_slice(&(dim as u32).to_le_bytes());
    write_frame(&mut conn.w, KIND_HELLO, &hello)?;
    conn.w.flush()?;
    Ok(conn)
}

/// What ended one connection's service loop.
enum ClientEnd {
    /// DONE received: the run is over.
    Done,
    /// The policy's scripted death fired after its round was read.
    Died,
}

/// One simulated client: HELLO, then execute every ROUND recipe through
/// the *same* fused pipeline the in-process workers run
/// ([`run_chunk`]), encode each channel's message with the wire codec,
/// and enforce the codec invariant (`bit_len == compressor-quoted
/// bits`) before sending. Under [`ClientPolicy::reconnect`] the client
/// treats a scripted death or a lost connection as a crash-restart:
/// it forgets its anchor replica (the coordinator resyncs dense on
/// rejoin), re-dials on its [`Backoff`] schedule, and serves on.
fn client_loop(
    addr: &str,
    client: usize,
    fleet: usize,
    dim: usize,
    comp: Option<&(String, usize, usize)>,
    oracle: &RustLogReg,
    policy: ClientPolicy,
) -> Result<()> {
    let mut backoff = Backoff::new(client as u64);
    let mut kit = FusedKit::default();
    let fork = match comp {
        Some((name, k, kp)) => Some(
            compressor_by_name(name, *k, *kp)?
                .fork()
                .with_context(|| format!("uplink compressor {name} has no sparse fork"))?,
        ),
        None => None,
    };
    let has_comp = fork.is_some();
    kit.install(fork);

    let mut input = PoolInput::default();
    input.cohort.push(client);
    input.scales.push(0.0);
    let mut out = WorkerOut::default();
    let mut frame = Vec::new();
    let mut msg = Vec::new();
    let mut w = BitWriter::new();
    let mut sv = SparseVec::default();
    // the client's persistent anchor replica + the server version it
    // holds — what delta ROUND frames patch in place
    let mut anchor: Vec<f32> = Vec::new();
    let mut aver: Option<u64> = None;
    let mut died = false;

    let mut conn = client_connect(addr, client, fleet, dim, &mut backoff)?;
    let mut restarts = 0usize;
    loop {
        let end = client_serve_conn(
            &mut conn, client, dim, has_comp, &mut kit, oracle, policy, &mut died, &mut input,
            &mut out, &mut frame, &mut msg, &mut w, &mut sv, &mut anchor, &mut aver,
        );
        match end {
            Ok(ClientEnd::Done) => return Ok(()),
            // a clean scripted death: the thread ends Ok — the server-
            // side record is the verdict on what the loss cost
            Ok(ClientEnd::Died) if !policy.reconnect => return Ok(()),
            Ok(ClientEnd::Died) => {}
            Err(e) if policy.reconnect && restarts < MAX_RECONNECTS => {
                restarts += 1;
                let _ = e;
            }
            Err(e) => return Err(e),
        }
        // crash-restart: drop the connection, forget the replica (the
        // coordinator resyncs a rejoiner dense), pace the re-dial
        drop(conn);
        anchor.clear();
        aver = None;
        std::thread::sleep(backoff.next_delay());
        conn = client_connect(addr, client, fleet, dim, &mut backoff)?;
    }
}

/// Serve one connection until DONE, a scripted death, or an error.
#[allow(clippy::too_many_arguments)]
fn client_serve_conn(
    conn: &mut Conn,
    client: usize,
    dim: usize,
    has_comp: bool,
    kit: &mut FusedKit,
    oracle: &RustLogReg,
    policy: ClientPolicy,
    died: &mut bool,
    input: &mut PoolInput,
    out: &mut WorkerOut,
    frame: &mut Vec<u8>,
    msg: &mut Vec<u8>,
    w: &mut BitWriter,
    sv: &mut SparseVec,
    anchor: &mut Vec<f32>,
    aver: &mut Option<u64>,
) -> Result<ClientEnd> {
    loop {
        let kind = read_frame(&mut conn.r, frame)
            .with_context(|| format!("client {client} reading from the coordinator"))?;
        match kind {
            KIND_DONE => return Ok(ClientEnd::Done),
            KIND_ROUND => {
                let layout = parse_round(frame, dim, input, anchor, aver)?;
                if !*died && policy.die_at == Some(input.round) {
                    // the scripted death: the ROUND was fully read (so
                    // the server cannot observe the EOF before this
                    // round's own event loop), no answer ever sent
                    *died = true;
                    return Ok(ClientEnd::Died);
                }
                let expect = if input.sup.is_empty() {
                    ensure!(has_comp, "unmasked round reached a compressor-less client");
                    LAYOUT_SPARSE
                } else if has_comp {
                    LAYOUT_MASKED_SPARSE
                } else {
                    LAYOUT_MASKED_RAW
                };
                ensure!(
                    layout == expect,
                    "coordinator negotiated layout {layout}, this client produces {expect}"
                );
                run_chunk(oracle, input, kit, out, 0, 1, dim)?;
                let round32 = input.round as u32;
                let mut off = 0usize;
                for (ch, &len) in out.lens.iter().enumerate() {
                    let (lo, hi) = (off, off + len as usize);
                    off = hi;
                    sv.clear(dim);
                    for (&i, &v) in out.idx[lo..hi].iter().zip(&out.val[lo..hi]) {
                        sv.push(i, v);
                    }
                    w.clear();
                    match layout {
                        LAYOUT_SPARSE => codec::encode_sparse(sv, w)?,
                        LAYOUT_MASKED_RAW => codec::encode_masked_raw(sv, &input.sup, w)?,
                        LAYOUT_MASKED_SPARSE => codec::encode_masked_sparse(sv, &input.sup, w)?,
                        _ => unreachable!("layout validated above"),
                    }
                    // the codec invariant, enforced on every live message
                    ensure!(
                        w.bit_len() == out.bits[ch],
                        "codec packed {} bits but the compressor quoted {} (client {client}, \
                         round {}, channel {ch})",
                        w.bit_len(),
                        out.bits[ch],
                        input.round
                    );
                    msg.clear();
                    msg.extend_from_slice(&round32.to_le_bytes());
                    msg.push(ch as u8);
                    msg.push(layout);
                    msg.extend_from_slice(&(sv.len() as u32).to_le_bytes());
                    msg.extend_from_slice(w.finish());
                    write_frame(&mut conn.w, KIND_MSG, msg)?;
                }
                conn.w.flush()?;
            }
            other => bail!("unexpected frame kind {other} from the coordinator"),
        }
    }
}

/// Parse a ROUND frame into the client's single-slot [`PoolInput`],
/// maintaining its persistent anchor replica: `AMODE_DENSE` replaces
/// the replica wholesale (first contact, or a planned resync);
/// `AMODE_DELTA` patches `m` exact `(index, new_f32)` pairs in place —
/// but only if the client holds exactly the base version the delta was
/// planned against, so a desynced replica dies loudly instead of
/// training on a silently wrong anchor. Returns the negotiated layout
/// byte.
fn parse_round(
    frame: &[u8],
    dim: usize,
    input: &mut PoolInput,
    anchor: &mut Vec<f32>,
    version: &mut Option<u64>,
) -> Result<u8> {
    let mut cur = Cur::new(frame);
    input.round = cur.u32()? as usize;
    input.seed = cur.u64()?;
    input.scales[0] = cur.f32()?;
    let layout = cur.u8()?;
    input.payload = match cur.u8()? {
        PAYLOAD_GRADIENT => FusedPayload::Gradient,
        PAYLOAD_LOCAL_SGD => {
            let steps = cur.u32()? as usize;
            let lr = cur.f32()?;
            let prox_mu = match cur.u8()? {
                0 => None,
                1 => Some(cur.f32()?),
                other => bail!("bad prox flag {other}"),
            };
            FusedPayload::LocalSgd { steps, lr, prox_mu }
        }
        other => bail!("unknown payload tag {other}"),
    };
    let d = cur.u32()? as usize;
    ensure!(d == dim, "round anchor dim {d} != client dim {dim}");
    match cur.u8()? {
        AMODE_DENSE => {
            let ver = cur.u64()?;
            anchor.clear();
            anchor.reserve(d);
            for _ in 0..d {
                anchor.push(cur.f32()?);
            }
            *version = Some(ver);
        }
        AMODE_DELTA => {
            let base = cur.u64()?;
            let ver = cur.u64()?;
            let m = cur.u32()? as usize;
            ensure!(
                *version == Some(base) && anchor.len() == d,
                "anchor delta against version {base}, but this client holds {version:?} — \
                 replica desync; the coordinator must resync dense"
            );
            ensure!(m <= d, "delta of {m} coords over dim {d}");
            // byte length is dictated by (m, d) — a truncated or padded
            // delta body can never parse
            let body = cur.take(codec::anchor_delta_bits(m, d).div_ceil(8) as usize)?;
            let mut r = BitReader::new(body);
            codec::decode_anchor_delta(&mut r, m, anchor)?;
            r.expect_zero_pad()?;
            *version = Some(ver);
        }
        other => bail!("unknown anchor mode {other}"),
    }
    input.point.clear();
    input.point.extend_from_slice(anchor);
    let nsup = cur.u32()? as usize;
    ensure!(nsup <= d, "support of {nsup} over dim {d}");
    input.sup.clear();
    input.sup.reserve(nsup);
    for _ in 0..nsup {
        input.sup.push(cur.u32()?);
    }
    ensure!(
        input.sup.windows(2).all(|p| p[0] < p[1]) && input.sup.iter().all(|&j| (j as usize) < d),
        "mask support must be strictly ascending within the model dimension"
    );
    cur.done()?;
    Ok(layout)
}
