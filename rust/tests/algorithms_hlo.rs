//! Integration: the paper's algorithms running end-to-end over the
//! HLO-backed oracles (the production compute path).

use std::rc::Rc;

use fedeff::algorithms::efbv::EfBv;
use fedeff::algorithms::scafflix::Scafflix;
use fedeff::algorithms::sppm::SppmAs;
use fedeff::algorithms::RunOptions;
use fedeff::compress::topk::TopK;
use fedeff::coordinator::driver::Driver;
use fedeff::data::synth::{logreg_dataset, Heterogeneity};
use fedeff::oracle::hlo::HloLogReg;
use fedeff::oracle::{solve_local, solve_reference, Oracle};
use fedeff::prox::LbfgsSolver;
use fedeff::runtime::Runtime;
use fedeff::sampling::NiceSampling;

fn oracle() -> Option<HloLogReg> {
    let rt = match Runtime::from_default_manifest() {
        Ok(rt) => Rc::new(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts: {e})");
            return None;
        }
    };
    let mut rng = fedeff::rng(200);
    let data = logreg_dataset(22, 256, 10, Heterogeneity::FeatureShift(0.5), 0.3, &mut rng);
    Some(HloLogReg::new(rt, "ijcnn1", data, 0.1).unwrap())
}

#[test]
fn efbv_on_hlo_oracle_converges() {
    let Some(o) = oracle() else { return };
    let d = o.dim();
    let (_, fs) = solve_reference(&o, &vec![0.0; d], 0.5, 3000, 1e-8).unwrap();
    let mut alg = EfBv::new(Box::new(TopK::new(4)));
    let opts =
        RunOptions { rounds: 300, eval_every: 50, f_star: Some(fs), seed: 1, ..Default::default() };
    let rec = Driver::new().run(&mut alg, &o, &vec![0.3; d], &opts).unwrap();
    let first = rec.rounds.first().unwrap().gap.unwrap();
    let last = rec.last().unwrap().gap.unwrap();
    assert!(last < first * 0.05, "gap {first} -> {last}");
}

#[test]
fn scafflix_on_hlo_oracle_converges() {
    let Some(o) = oracle() else { return };
    let d = o.dim();
    let x_stars: Vec<Vec<f32>> = (0..o.n_clients())
        .map(|i| solve_local(&o, i, &vec![0.0; d], 0.5, 1500, 1e-6).unwrap())
        .collect();
    let mut alg = Scafflix::standard(&o, 0.5, 0.3, x_stars);
    let opts = RunOptions { rounds: 400, eval_every: 100, seed: 2, ..Default::default() };
    let rec = Driver::new().run(&mut alg, &o, &vec![0.5; d], &opts).unwrap();
    let first = rec.rounds.first().unwrap().loss;
    let last = rec.last().unwrap().loss;
    assert!(last < first, "FLIX loss {first} -> {last}");
}

#[test]
fn sppm_on_hlo_oracle_reaches_neighborhood() {
    let Some(o) = oracle() else { return };
    let d = o.dim();
    let (xs, _) = solve_reference(&o, &vec![0.0; d], 0.5, 3000, 1e-8).unwrap();
    let mut alg = SppmAs::new(Box::new(LbfgsSolver::default()), 50.0, 10);
    let drv = Driver::new().with_sampler(Box::new(NiceSampling { n: 10, tau: 4 }));
    let opts =
        RunOptions { rounds: 25, eval_every: 5, x_star: Some(xs), seed: 3, ..Default::default() };
    let rec = drv.run(&mut alg, &o, &vec![1.0; d], &opts).unwrap();
    let first = rec.rounds.first().unwrap().gap.unwrap();
    let last = rec.last().unwrap().gap.unwrap();
    assert!(last < first * 0.05, "dist^2 {first} -> {last}");
}
