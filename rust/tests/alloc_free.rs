//! Zero-per-round-allocation guarantee for the driver hot path.
//!
//! Strategy: a counting global allocator, and two runs of the same
//! configuration that differ only in round count (evals pinned to t=0 +
//! final in both). If steady-state rounds allocated anything, the longer
//! run would count more allocations; equality proves the per-round path
//! is allocation-free — for the dense GD path, for the sparse Top-K
//! compressed path (reusable selection scratch + `SparseVec` buffers),
//! and for the fused worker-pool path, where every per-round hand-off
//! (job slots, done gate, message batches, replay) must reuse
//! spawn-time capacity: the pool signals through mutex/condvar slots
//! precisely because channel sends allocate. The fused case also pins
//! the no-dense-hand-off property indirectly — a `cohort·d` staging
//! buffer would have to grow on the first post-warmup round and show up
//! in the count.
//!
//! Keep this file to a single `#[test]`: the counter is process-global,
//! and a second concurrently-running test would pollute the window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use fedeff::algorithms::gd::Gd;
use fedeff::algorithms::RunOptions;
use fedeff::compress::topk::TopK;
use fedeff::coordinator::driver::Driver;
use fedeff::oracle::quadratic::QuadraticOracle;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct Counting;

// SAFETY: delegates allocation to `System` unchanged; only counts.
unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
}

#[global_allocator]
static COUNTER: Counting = Counting;

#[derive(Clone, Copy)]
enum Mode {
    /// Serial driver, dense uplink.
    DenseSerial,
    /// Serial driver, sparse Top-K uplink.
    TopkSerial,
    /// Fused worker-pool run: in-worker Top-K compression, per-worker
    /// message batches, driver-side replay. Setup (thread spawn, kit
    /// sizing) allocates once per run — identical in both runs — and
    /// steady-state rounds must add nothing.
    TopkFusedPool,
}

/// Allocation count of one full deterministic run (setup + init + two
/// evals + `rounds` steady-state rounds).
fn allocs_for(rounds: usize, mode: Mode) -> u64 {
    let mut rng = fedeff::rng(7);
    let q = QuadraticOracle::random(8, 64, 0.5, 2.0, 1.0, &mut rng);
    let mut alg = Gd::plain(8, 64, 0.2);
    let drv = match mode {
        Mode::DenseSerial => Driver::new(),
        _ => Driver::new().with_up(Box::new(TopK::new(8))),
    };
    // evals only at t=0 and the final record: identical in both runs
    let opts = RunOptions { rounds, eval_every: 1 << 30, ..Default::default() };
    let x0 = vec![0.5f32; 64];
    let before = ALLOCS.load(Ordering::Relaxed);
    let rec = match mode {
        Mode::TopkFusedPool => drv.run_parallel(&mut alg, &q, &x0, &opts).unwrap(),
        _ => drv.run(&mut alg, &q, &x0, &opts).unwrap(),
    };
    let after = ALLOCS.load(Ordering::Relaxed);
    assert!(rec.last().unwrap().loss.is_finite());
    after - before
}

#[test]
fn steady_state_rounds_do_not_allocate() {
    for (label, mode) in [
        ("dense GD", Mode::DenseSerial),
        ("sparse Top-K GD", Mode::TopkSerial),
        ("fused Top-K GD pool", Mode::TopkFusedPool),
    ] {
        let _warmup = allocs_for(10, mode);
        let base = allocs_for(50, mode);
        let double = allocs_for(100, mode);
        assert_eq!(
            double, base,
            "{label}: 100-round run allocated {double} vs {base} for 50 rounds — steady-state rounds must be allocation-free"
        );
    }
}
