//! The redesign's safety net: the `Driver`-based algorithms must
//! reproduce the seed (pre-driver) hand-rolled loops *bit-for-bit* on the
//! quadratic oracle at fixed seeds. The reference loops below are verbatim
//! copies of the seed implementations of GD, FedAvg and Scafflix.
//!
//! Also covers the registry (every advertised name constructs and runs),
//! the two previously-impossible compositions the redesign opens
//! (Scafflix with Top-K uplink compression and FedAvg costed over a
//! 2-level hierarchy — both reachable from a TOML spec), the sparse
//! message fast path (runs over the O(k) sparse link path must match the
//! dense reference path bit-for-bit in loss and booked bits), the
//! executed multi-level aggregation trees (depth-1 and pass-through
//! trees must reproduce the flat driver bit-for-bit, hub order must not
//! matter beyond floating-point summation order, and per-edge
//! re-compression must book strictly fewer hub→server bits than the
//! flat run), and the fused uplink pipeline: with per-client
//! compression streams, the in-worker fused path, the reference pool
//! path (`with_fused_uplink(false)`) and the fully serial driver must
//! produce bit-for-bit identical records for every plan-capable
//! algorithm across flat, 3-level tree, masked and sampled runs.

use fedeff::algorithms::gd::{FlixGd, Gd};
use fedeff::algorithms::scafflix::Scafflix;
use fedeff::algorithms::{build_algorithm, registry, RunOptions};
use fedeff::compress::sparse_bits;
use fedeff::coordinator::driver::{Driver, Topology};
use fedeff::coordinator::hierarchy::{AggTree, Hierarchy};
use fedeff::metrics::RunRecord;
use fedeff::oracle::quadratic::QuadraticOracle;
use fedeff::oracle::{solve_local, Oracle};
use fedeff::sampling::{CohortSampler, NiceSampling};
use fedeff::vecmath as vm;

type Series = Vec<(f32, Option<f32>)>;

fn series_of(rec: &RunRecord) -> Series {
    rec.rounds.iter().map(|r| (r.loss, r.gap)).collect()
}

fn assert_series_eq(driver: &Series, seed: &Series, what: &str) {
    assert_eq!(driver.len(), seed.len(), "{what}: series lengths differ");
    for (i, (d, s)) in driver.iter().zip(seed).enumerate() {
        assert!(
            d.0 == s.0 && d.1 == s.1,
            "{what}: entry {i} differs: driver {d:?} vs seed {s:?}"
        );
    }
}

fn erm_eval(q: &QuadraticOracle, x: &[f32], opts: &RunOptions) -> (f32, Option<f32>) {
    let mut g = vec![0.0f32; q.dim()];
    let loss = q.full_loss_grad(x, &mut g).unwrap();
    let gap = match (opts.f_star, &opts.x_star) {
        (Some(fs), _) => Some(loss - fs),
        (None, Some(xs)) => Some(vm::dist_sq(x, xs)),
        _ => None,
    };
    (loss, gap)
}

/// Verbatim copy of the seed `FlixGd::run` loop (loss/gap series only).
fn seed_gd_series(flix: &FlixGd, q: &QuadraticOracle, x0: &[f32], opts: &RunOptions) -> Series {
    let d = q.dim();
    let mut x = x0.to_vec();
    let mut g = vec![0.0f32; d];
    let mut out = Vec::new();
    for t in 0..opts.rounds {
        let loss = flix.flix_loss_grad(q, &x, &mut g).unwrap();
        if t % opts.eval_every == 0 {
            out.push((loss, opts.f_star.map(|fs| loss - fs)));
        }
        vm::axpy(-flix.gamma, &g, &mut x);
    }
    // seed final record: ERM record_eval, loss/gap then fixed to FLIX
    let loss = flix.flix_loss(q, &x).unwrap();
    out.push((loss, opts.f_star.map(|fs| loss - fs)));
    out
}

/// Verbatim copy of the seed `FedAvg::run` loop.
#[allow(clippy::too_many_arguments)]
fn seed_fedavg_series(
    q: &QuadraticOracle,
    sampler: &NiceSampling,
    local_steps: usize,
    lr: f32,
    dropout: f32,
    x0: &[f32],
    opts: &RunOptions,
) -> Series {
    let d = q.dim();
    let mut rng = fedeff::rng(opts.seed);
    let mut x = x0.to_vec();
    let mut g = vec![0.0f32; d];
    let mut xi = vec![0.0f32; d];
    let mut next = vec![0.0f32; d];
    let mut out = Vec::new();
    for t in 0..opts.rounds {
        if t % opts.eval_every == 0 {
            out.push(erm_eval(q, &x, opts));
        }
        let mut cohort = sampler.sample(&mut rng);
        if dropout > 0.0 {
            cohort.retain(|_| !rng.bernoulli(dropout));
        }
        if cohort.is_empty() {
            continue; // wasted round: every sampled client dropped
        }
        next.fill(0.0);
        for &i in &cohort {
            xi.copy_from_slice(&x);
            for _ in 0..local_steps {
                q.loss_grad(i, &xi, &mut g).unwrap();
                vm::axpy(-lr, &g, &mut xi);
            }
            vm::acc_mean(&xi, cohort.len() as f32, &mut next);
        }
        x.copy_from_slice(&next);
    }
    out.push(erm_eval(q, &x, opts));
    out
}

/// Verbatim copy of the seed `Scafflix::run` loop.
#[allow(clippy::too_many_arguments)]
fn seed_scafflix_series(
    q: &QuadraticOracle,
    alphas: &[f32],
    x_stars: &[Vec<f32>],
    gammas: &[f32],
    p: f32,
    clients_per_round: Option<usize>,
    x0: &[f32],
    opts: &RunOptions,
) -> Series {
    fn flixify(alphas: &[f32], x_stars: &[Vec<f32>], i: usize, x: &[f32], out: &mut [f32]) {
        let a = alphas[i];
        for j in 0..x.len() {
            out[j] = a * x[j] + (1.0 - a) * x_stars[i][j];
        }
    }
    let d = q.dim();
    let n = q.n_clients();
    let flix = FlixGd { alphas: alphas.to_vec(), x_stars: x_stars.to_vec(), gamma: 0.0 };
    let gamma_srv = 1.0
        / ((0..n).map(|i| alphas[i] * alphas[i] / gammas[i]).sum::<f32>() / n as f32);
    let mut rng = fedeff::rng(opts.seed);
    let mut x_i = vec![x0.to_vec(); n];
    let mut h_i = vec![vec![0.0f32; d]; n];
    let mut hat = vec![vec![0.0f32; d]; n];
    let mut tilde = vec![0.0f32; d];
    let mut g = vec![0.0f32; d];
    let mut xbar = vec![0.0f32; d];
    let mut out = Vec::new();

    for t in 0..opts.rounds {
        if t % opts.eval_every == 0 {
            xbar.fill(0.0);
            for xi in &x_i {
                vm::acc_mean(xi, n as f32, &mut xbar);
            }
            let loss = flix.flix_loss(q, &xbar).unwrap();
            out.push((loss, opts.f_star.map(|fs| loss - fs)));
        }
        for i in 0..n {
            flixify(alphas, x_stars, i, &x_i[i], &mut tilde);
            q.loss_grad(i, &tilde, &mut g).unwrap();
            let step = gammas[i] / alphas[i].max(1e-8);
            for j in 0..d {
                hat[i][j] = x_i[i][j] - step * (g[j] - h_i[i][j]);
            }
        }
        if rng.f32_unit() < p {
            let participants: Vec<usize> = match clients_per_round {
                None => (0..n).collect(),
                Some(tau) => {
                    let mut idx: Vec<usize> = (0..n).collect();
                    rng.shuffle(&mut idx);
                    idx.truncate(tau.min(n));
                    idx
                }
            };
            let norm = participants.len() as f32;
            xbar.fill(0.0);
            for &jc in &participants {
                let w = gamma_srv * alphas[jc] * alphas[jc] / gammas[jc] / norm;
                vm::axpy(w, &hat[jc], &mut xbar);
            }
            for &i in &participants {
                let coef = p * alphas[i] / gammas[i];
                for j in 0..d {
                    h_i[i][j] += coef * (xbar[j] - hat[i][j]);
                }
                x_i[i].copy_from_slice(&xbar);
            }
            for i in 0..n {
                if !participants.contains(&i) {
                    x_i[i].copy_from_slice(&hat[i]);
                }
            }
        } else {
            for i in 0..n {
                x_i[i].copy_from_slice(&hat[i]);
            }
        }
    }
    xbar.fill(0.0);
    for xi in &x_i {
        vm::acc_mean(xi, n as f32, &mut xbar);
    }
    let loss = flix.flix_loss(q, &xbar).unwrap();
    out.push((loss, opts.f_star.map(|fs| loss - fs)));
    out
}

fn quadratic(seed: u64, n: usize, d: usize) -> QuadraticOracle {
    let mut rng = fedeff::rng(seed);
    QuadraticOracle::random(n, d, 0.5, 2.0, 1.0, &mut rng)
}

#[test]
fn driver_gd_matches_seed_loop_plain() {
    let q = quadratic(27, 4, 6);
    let xs = q.minimizer();
    let fs = q.full_loss(&xs).unwrap();
    let x0 = vec![1.0f32; 6];
    let opts =
        RunOptions { rounds: 120, eval_every: 10, f_star: Some(fs), seed: 7, ..Default::default() };
    let flix = FlixGd::plain(4, 6, 0.4);
    let expected = seed_gd_series(&flix, &q, &x0, &opts);
    let mut alg = Gd::new(flix);
    let rec = Driver::new().run(&mut alg, &q, &x0, &opts).unwrap();
    assert_series_eq(&series_of(&rec), &expected, "plain GD");
}

#[test]
fn driver_gd_matches_seed_loop_personalized() {
    let q = quadratic(28, 5, 7);
    let x_stars: Vec<Vec<f32>> = (0..5)
        .map(|i| solve_local(&q, i, &vec![0.0; 7], 0.3, 600, 1e-7).unwrap())
        .collect();
    let flix = FlixGd { alphas: vec![0.5; 5], x_stars, gamma: 0.3 };
    let x0 = vec![2.0f32; 7];
    let opts = RunOptions { rounds: 90, eval_every: 15, seed: 11, ..Default::default() };
    let expected = seed_gd_series(&flix, &q, &x0, &opts);
    let mut alg = Gd::new(flix.clone());
    let rec = Driver::new().run(&mut alg, &q, &x0, &opts).unwrap();
    assert_series_eq(&series_of(&rec), &expected, "personalized GD");
}

#[test]
fn driver_fedavg_matches_seed_loop() {
    let q = quadratic(33, 6, 6);
    let xs = q.minimizer();
    let x0 = vec![3.0f32; 6];
    let opts = RunOptions {
        rounds: 150,
        eval_every: 10,
        x_star: Some(xs),
        seed: 4,
        ..Default::default()
    };
    let sampler = NiceSampling { n: 6, tau: 3 };
    let expected = seed_fedavg_series(&q, &sampler, 5, 0.1, 0.0, &x0, &opts);
    let mut alg = fedeff::algorithms::fedavg::FedAvg::new(5, 0.1);
    let drv = Driver::new().with_sampler(Box::new(NiceSampling { n: 6, tau: 3 }));
    let rec = drv.run(&mut alg, &q, &x0, &opts).unwrap();
    assert_series_eq(&series_of(&rec), &expected, "FedAvg");
}

#[test]
fn driver_fedavg_matches_seed_loop_with_dropout() {
    let q = quadratic(35, 6, 5);
    let xs = q.minimizer();
    let fs = q.full_loss(&xs).unwrap();
    let x0 = vec![2.0f32; 5];
    let opts = RunOptions {
        rounds: 200,
        eval_every: 25,
        f_star: Some(fs),
        seed: 9,
        ..Default::default()
    };
    let sampler = NiceSampling { n: 6, tau: 3 };
    let expected = seed_fedavg_series(&q, &sampler, 2, 0.2, 0.5, &x0, &opts);
    let mut alg = fedeff::algorithms::fedavg::FedAvg::new(2, 0.2);
    alg.dropout = 0.5;
    let drv = Driver::new().with_sampler(Box::new(NiceSampling { n: 6, tau: 3 }));
    let rec = drv.run(&mut alg, &q, &x0, &opts).unwrap();
    assert_series_eq(&series_of(&rec), &expected, "FedAvg+dropout");
}

#[test]
fn driver_scafflix_matches_seed_loop() {
    let q = quadratic(31, 6, 8);
    let x_stars: Vec<Vec<f32>> = (0..6)
        .map(|i| solve_local(&q, i, &vec![0.0; 8], 0.3, 800, 1e-8).unwrap())
        .collect();
    let gammas: Vec<f32> = (0..6).map(|i| 1.0 / q.smoothness(i)).collect();
    let alphas = vec![0.5f32; 6];
    let x0 = vec![1.0f32; 8];
    let opts = RunOptions { rounds: 200, eval_every: 20, seed: 2, ..Default::default() };
    let expected =
        seed_scafflix_series(&q, &alphas, &x_stars, &gammas, 0.3, None, &x0, &opts);
    let mut alg = Scafflix::standard(&q, 0.5, 0.3, x_stars);
    let rec = Driver::new().run(&mut alg, &q, &x0, &opts).unwrap();
    assert_series_eq(&series_of(&rec), &expected, "Scafflix");
}

#[test]
fn driver_scafflix_matches_seed_loop_partial_participation() {
    let q = quadratic(32, 6, 8);
    let x_stars: Vec<Vec<f32>> = (0..6)
        .map(|i| solve_local(&q, i, &vec![0.0; 8], 0.3, 800, 1e-8).unwrap())
        .collect();
    let gammas: Vec<f32> = (0..6).map(|i| 1.0 / q.smoothness(i)).collect();
    let alphas = vec![0.5f32; 6];
    let x0 = vec![1.0f32; 8];
    let opts = RunOptions { rounds: 250, eval_every: 50, seed: 4, ..Default::default() };
    let expected =
        seed_scafflix_series(&q, &alphas, &x_stars, &gammas, 0.5, Some(3), &x0, &opts);
    let mut alg = Scafflix::standard(&q, 0.5, 0.5, x_stars);
    alg.clients_per_round = Some(3);
    let rec = Driver::new().run(&mut alg, &q, &x0, &opts).unwrap();
    assert_series_eq(&series_of(&rec), &expected, "Scafflix partial");
}

#[test]
fn registry_every_name_constructs_and_runs() {
    let q = quadratic(99, 4, 6);
    for name in registry() {
        let spec = fedeff::config::AlgorithmSpec {
            kind: name.to_string(),
            k: Some(2),
            ..Default::default()
        };
        let mut alg = build_algorithm(&spec, &q)
            .unwrap_or_else(|e| panic!("registry name {name} failed to build: {e}"));
        let opts = RunOptions { rounds: 2, eval_every: 1, ..Default::default() };
        let rec = Driver::new()
            .run(alg.as_mut(), &q, &vec![1.0; 6], &opts)
            .unwrap_or_else(|e| panic!("registry name {name} failed to run: {e}"));
        assert_eq!(rec.rounds.len(), 3, "{name}: expected evals at t=0,1 and final");
        assert!(rec.last().unwrap().loss.is_finite(), "{name}: non-finite loss");
    }
}

#[test]
fn composition_scafflix_with_topk_uplink() {
    // previously impossible: the seed Scafflix had no compressor slot
    let q = quadratic(41, 6, 8);
    let x_stars: Vec<Vec<f32>> = (0..6)
        .map(|i| solve_local(&q, i, &vec![0.0; 8], 0.3, 800, 1e-8).unwrap())
        .collect();
    let mut alg = Scafflix::standard(&q, 0.5, 0.3, x_stars);
    let opts = RunOptions { rounds: 400, eval_every: 400, seed: 6, ..Default::default() };
    let drv = Driver::new().with_up(Box::new(fedeff::compress::topk::TopK::new(4)));
    let rec = drv.run(&mut alg, &q, &vec![2.0; 8], &opts).unwrap();
    let first = rec.rounds.first().unwrap().loss;
    let last = rec.last().unwrap().loss;
    assert!(last.is_finite() && last < first, "compressed Scafflix: {first} -> {last}");
    // compressed uplink books fewer bits than the dense downlink
    let r = rec.last().unwrap();
    assert!(r.bits_up < r.bits_down, "up {} vs down {}", r.bits_up, r.bits_down);
}

#[test]
fn composition_fedavg_over_hierarchy() {
    // previously impossible: the seed FedAvg only had a scalar cost knob,
    // now any algorithm runs over a 2-level topology via the driver
    let q = quadratic(42, 6, 5);
    let mut alg = fedeff::algorithms::fedavg::FedAvg::new(3, 0.1);
    let opts = RunOptions { rounds: 20, eval_every: 20, ..Default::default() };
    let drv = Driver::new()
        .with_sampler(Box::new(NiceSampling { n: 6, tau: 3 }))
        .with_topology(Topology::Hier(Hierarchy::even(6, 2, 0.05, 1.0)));
    let rec = drv.run(&mut alg, &q, &vec![1.0; 5], &opts).unwrap();
    let cost = rec.last().unwrap().comm_cost;
    assert!((cost - 20.0 * 1.05).abs() < 1e-9, "hierarchical cost {cost}");
}

/// Assert two records are bit-for-bit identical in loss and in the
/// cumulative per-node bits on both links.
fn assert_records_bitwise_eq(
    a: &fedeff::metrics::RunRecord,
    b: &fedeff::metrics::RunRecord,
    what: &str,
) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: record lengths differ");
    for (i, (ra, rb)) in a.rounds.iter().zip(&b.rounds).enumerate() {
        assert!(ra.loss == rb.loss, "{what}: entry {i} loss {} vs {}", ra.loss, rb.loss);
        assert_eq!(ra.bits_up, rb.bits_up, "{what}: entry {i} bits_up");
        assert_eq!(ra.bits_down, rb.bits_down, "{what}: entry {i} bits_down");
    }
}

#[test]
fn sparse_path_matches_dense_gd_topk() {
    let q = quadratic(60, 6, 64);
    let x0 = vec![1.0f32; 64];
    let opts = RunOptions { rounds: 80, eval_every: 10, seed: 3, ..Default::default() };
    let mut a = Gd::plain(6, 64, 0.1);
    let rec_dense = Driver::new()
        .with_up(Box::new(fedeff::compress::topk::TopK::new(8)))
        .with_sparse_links(false)
        .run(&mut a, &q, &x0, &opts)
        .unwrap();
    let mut b = Gd::plain(6, 64, 0.1);
    let rec_sparse = Driver::new()
        .with_up(Box::new(fedeff::compress::topk::TopK::new(8)))
        .run(&mut b, &q, &x0, &opts)
        .unwrap();
    assert_records_bitwise_eq(&rec_dense, &rec_sparse, "GD+TopK");
    // the compressed uplink actually booked sparse-message bits
    let dense_bits = 32u64 * 64 * 80;
    assert!(rec_sparse.last().unwrap().bits_up < dense_bits);
}

#[test]
fn sparse_path_matches_dense_ef21_topk() {
    let q = quadratic(61, 8, 48);
    let x0 = vec![1.0f32; 48];
    let opts = RunOptions { rounds: 120, eval_every: 20, seed: 8, ..Default::default() };
    let mut a =
        fedeff::algorithms::efbv::EfBv::ef21(Box::new(fedeff::compress::topk::TopK::new(6)));
    let rec_dense = Driver::new()
        .with_sparse_links(false)
        .run(&mut a, &q, &x0, &opts)
        .unwrap();
    let mut b =
        fedeff::algorithms::efbv::EfBv::ef21(Box::new(fedeff::compress::topk::TopK::new(6)));
    let rec_sparse = Driver::new().run(&mut b, &q, &x0, &opts).unwrap();
    assert_records_bitwise_eq(&rec_dense, &rec_sparse, "EF21+TopK");
}

#[test]
fn sparse_path_matches_dense_fedavg_randk() {
    // FedCOM delta compression on both links under partial participation:
    // Rand-K draws from the link RNG, which both paths must consume
    // identically
    let q = quadratic(62, 8, 32);
    let x0 = vec![2.0f32; 32];
    let opts = RunOptions { rounds: 100, eval_every: 20, seed: 13, ..Default::default() };
    let mk = |sparse: bool| {
        Driver::new()
            .with_sampler(Box::new(NiceSampling { n: 8, tau: 4 }))
            .with_up(Box::new(fedeff::compress::randk::RandK::scaled(5)))
            .with_down(Box::new(fedeff::compress::randk::RandK::scaled(5)))
            .with_sparse_links(sparse)
    };
    let mut a = fedeff::algorithms::fedavg::FedAvg::new(3, 0.1);
    let rec_dense = mk(false).run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = fedeff::algorithms::fedavg::FedAvg::new(3, 0.1);
    let rec_sparse = mk(true).run(&mut b, &q, &x0, &opts).unwrap();
    assert_records_bitwise_eq(&rec_dense, &rec_sparse, "FedAvg+RandK");
}

#[test]
fn sparse_path_matches_dense_scaffold_topk() {
    let q = quadratic(63, 6, 40);
    let x0 = vec![1.5f32; 40];
    let opts = RunOptions { rounds: 100, eval_every: 25, seed: 17, ..Default::default() };
    let mk = |sparse: bool| {
        Driver::new()
            .with_sampler(Box::new(NiceSampling { n: 6, tau: 3 }))
            .with_up(Box::new(fedeff::compress::topk::TopK::new(5)))
            .with_sparse_links(sparse)
    };
    let mut a = fedeff::algorithms::scaffold::Scaffold::new(3, 0.05);
    let rec_dense = mk(false).run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = fedeff::algorithms::scaffold::Scaffold::new(3, 0.05);
    let rec_sparse = mk(true).run(&mut b, &q, &x0, &opts).unwrap();
    assert_records_bitwise_eq(&rec_dense, &rec_sparse, "Scaffold+TopK");
}

#[test]
fn toml_spec_drives_registry_and_compositions() {
    // end-to-end: TOML -> Spec -> registry build + driver build -> run
    let toml = r#"
[experiment]
name = "compose-e2e"
rounds = 4

[dataset]
clients = 4

[algorithm]
kind = "scafflix"
alpha = 0.5
p = 0.5

[compressor]
up = "top-k"
k = 3

[topology]
hubs = 2
c1 = 0.05
c2 = 1.0
"#;
    let spec = fedeff::config::Spec::parse(toml).unwrap();
    let q = quadratic(50, 4, 6);
    let mut alg = build_algorithm(&spec.algorithm, &q).unwrap();
    let driver = fedeff::config::build_driver(&spec, 4).unwrap();
    let opts = RunOptions {
        rounds: spec.experiment.rounds,
        eval_every: spec.experiment.eval_every,
        seed: spec.experiment.seed,
        ..Default::default()
    };
    let rec = driver.run(alg.as_mut(), &q, &vec![1.0; 6], &opts).unwrap();
    assert!(rec.last().unwrap().loss.is_finite());

    // and a second composition from TOML: fedavg over the same hierarchy
    let toml2 = toml
        .replace("kind = \"scafflix\"", "kind = \"fedavg\"")
        .replace("alpha = 0.5", "local_steps = 2")
        .replace("p = 0.5", "lr = 0.1");
    let spec2 = fedeff::config::Spec::parse(&toml2).unwrap();
    let mut alg2 = build_algorithm(&spec2.algorithm, &q).unwrap();
    let driver2 = fedeff::config::build_driver(&spec2, 4).unwrap();
    let rec2 = driver2.run(alg2.as_mut(), &q, &vec![1.0; 6], &opts).unwrap();
    assert!(rec2.last().unwrap().loss.is_finite());
    // hierarchy pricing applied: fedavg communicates every round
    assert!((rec2.last().unwrap().comm_cost - 4.0 * 1.05).abs() < 1e-9);
}

// ---------------------------------------------------------------------------
// Executed multi-level aggregation trees (Cohort-Squeeze execution path)
// ---------------------------------------------------------------------------

/// A depth-1 tree (clients -> server, no internal nodes) is the flat
/// driver by construction: identical losses and identical booked bits.
#[test]
fn tree_depth1_matches_flat_bitwise() {
    let q = quadratic(70, 6, 32);
    let x0 = vec![1.0f32; 32];
    let opts = RunOptions { rounds: 60, eval_every: 15, seed: 3, ..Default::default() };
    let mut a = Gd::plain(6, 32, 0.1);
    let rec_flat = Driver::new()
        .with_up(Box::new(fedeff::compress::topk::TopK::new(6)))
        .run(&mut a, &q, &x0, &opts)
        .unwrap();
    let mut b = Gd::plain(6, 32, 0.1);
    let rec_tree = Driver::new()
        .with_up(Box::new(fedeff::compress::topk::TopK::new(6)))
        .with_topology(Topology::Tree(AggTree::even(6, &[], vec![1.0])))
        .run(&mut b, &q, &x0, &opts)
        .unwrap();
    assert_records_bitwise_eq(&rec_flat, &rec_tree, "depth-1 tree vs flat");
    // the degenerate tree still reports its (single) edge class
    assert_eq!(rec_tree.edge_bits_up.len(), 1);
    assert!(rec_tree.edge_bits_up[0] > 0);
    // same cost model as flat (costs = [1.0])
    assert_eq!(
        rec_flat.last().unwrap().comm_cost,
        rec_tree.last().unwrap().comm_cost,
    );
}

/// A 2-level tree whose internal edge carries no compressor is pure
/// pass-through: hubs forward their children's messages unchanged, so
/// GD aggregates bit-for-bit like the flat driver.
#[test]
fn tree_2level_identity_matches_flat_gd() {
    let q = quadratic(71, 8, 24);
    let x0 = vec![2.0f32; 24];
    let opts = RunOptions { rounds: 80, eval_every: 20, seed: 5, ..Default::default() };
    let mk_sampler = || Box::new(NiceSampling { n: 8, tau: 4 });
    let mut a = Gd::plain(8, 24, 0.15);
    let rec_flat =
        Driver::new().with_sampler(mk_sampler()).run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = Gd::plain(8, 24, 0.15);
    let rec_tree = Driver::new()
        .with_sampler(mk_sampler())
        .with_topology(Topology::Tree(AggTree::even(8, &[2], vec![1.0, 0.0])))
        .run(&mut b, &q, &x0, &opts)
        .unwrap();
    assert_records_bitwise_eq(&rec_flat, &rec_tree, "2-level identity tree GD");
    // costs [1, 0] price rounds exactly like flat, so even comm_cost pins
    assert_eq!(
        rec_flat.last().unwrap().comm_cost,
        rec_tree.last().unwrap().comm_cost,
    );
}

/// Same pass-through equivalence for FedAvg with a Top-K uplink: the
/// FedCOM delta messages compress at the leaf edge, hubs relay them
/// unchanged, the server sees exactly the flat aggregate.
#[test]
fn tree_2level_identity_matches_flat_fedavg_topk() {
    let q = quadratic(72, 9, 30);
    let x0 = vec![1.5f32; 30];
    let opts = RunOptions { rounds: 100, eval_every: 25, seed: 7, ..Default::default() };
    let mk = |tree: bool| {
        let d = Driver::new()
            .with_sampler(Box::new(NiceSampling { n: 9, tau: 5 }))
            .with_up(Box::new(fedeff::compress::topk::TopK::new(5)));
        if tree {
            d.with_topology(Topology::Tree(AggTree::even(9, &[3], vec![1.0, 0.0])))
        } else {
            d
        }
    };
    let mut a = fedeff::algorithms::fedavg::FedAvg::new(3, 0.1);
    let rec_flat = mk(false).run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = fedeff::algorithms::fedavg::FedAvg::new(3, 0.1);
    let rec_tree = mk(true).run(&mut b, &q, &x0, &opts).unwrap();
    assert_records_bitwise_eq(&rec_flat, &rec_tree, "2-level identity tree FedAvg+TopK");
    // pass-through hubs relay the leaf payloads: the internal edge saw
    // exactly the leaf edge's traffic
    assert_eq!(rec_tree.edge_bits_up[1], rec_tree.edge_bits_up[0]);
}

/// Scaffold (two uplink messages per client per round) over a 2-level
/// identity tree also reproduces the flat driver bit-for-bit.
#[test]
fn tree_2level_identity_matches_flat_scaffold() {
    let q = quadratic(73, 6, 20);
    let x0 = vec![2.0f32; 20];
    let opts = RunOptions { rounds: 120, eval_every: 30, seed: 11, ..Default::default() };
    let mk_sampler = || Box::new(NiceSampling { n: 6, tau: 3 });
    let mut a = fedeff::algorithms::scaffold::Scaffold::new(3, 0.05);
    let rec_flat =
        Driver::new().with_sampler(mk_sampler()).run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = fedeff::algorithms::scaffold::Scaffold::new(3, 0.05);
    let rec_tree = Driver::new()
        .with_sampler(mk_sampler())
        .with_topology(Topology::Tree(AggTree::even(6, &[2], vec![1.0, 0.0])))
        .run(&mut b, &q, &x0, &opts)
        .unwrap();
    assert_records_bitwise_eq(&rec_flat, &rec_tree, "2-level identity tree Scaffold");
}

/// Relabeling hubs (same partition, different hub ids) only changes the
/// order partial aggregates reach the server accumulator, i.e. pure
/// floating-point reassociation. With deterministic Top-K edges the
/// final losses agree to ~1e-4 relative — the bound documents the f32
/// summation-order drift over 10 rounds, not an algorithmic difference.
#[test]
fn tree_hub_order_permutation_invariance() {
    let q = quadratic(74, 6, 40);
    let x0 = vec![1.0f32; 40];
    let opts = RunOptions { rounds: 10, eval_every: 10, ..Default::default() };
    // partition {0,1} {2,3} {4,5}, hubs in natural vs permuted order
    let natural =
        AggTree::new(vec![vec![0, 0, 1, 1, 2, 2], vec![0, 0, 0]], vec![1.0, 0.0]).unwrap();
    let permuted =
        AggTree::new(vec![vec![2, 2, 0, 0, 1, 1], vec![0, 0, 0]], vec![1.0, 0.0]).unwrap();
    let run = |tree: AggTree| {
        let mut alg = Gd::plain(6, 40, 0.1);
        Driver::new()
            .with_up(Box::new(fedeff::compress::topk::TopK::new(10)))
            .with_up_edge(1, Box::new(fedeff::compress::topk::TopK::new(20)))
            .with_topology(Topology::Tree(tree))
            .run(&mut alg, &q, &x0, &opts)
            .unwrap()
    };
    let rec_a = run(natural);
    let rec_b = run(permuted);
    // bits are exactly equal (same messages, same sizes)...
    assert_eq!(rec_a.edge_bits_up, rec_b.edge_bits_up);
    let (la, lb) = (rec_a.last().unwrap().loss, rec_b.last().unwrap().loss);
    // ...losses agree within the documented fp-reassociation tolerance
    let tol = 1e-4 * la.abs().max(1.0);
    assert!((la - lb).abs() <= tol, "hub permutation drifted: {la} vs {lb}");
}

/// The O(k) sparse scatter path must match the dense reference path
/// bit-for-bit when hubs re-compress partial aggregates too.
#[test]
fn tree_sparse_matches_dense_with_hub_compression() {
    let q = quadratic(75, 8, 48);
    let x0 = vec![1.0f32; 48];
    let opts = RunOptions { rounds: 60, eval_every: 15, seed: 9, ..Default::default() };
    let mk = |sparse: bool| {
        Driver::new()
            .with_up(Box::new(fedeff::compress::topk::TopK::new(6)))
            .with_up_edge(1, Box::new(fedeff::compress::topk::TopK::new(12)))
            .with_topology(Topology::Tree(AggTree::even(8, &[2], vec![1.0, 0.0])))
            .with_sparse_links(sparse)
    };
    let mut a = Gd::plain(8, 48, 0.1);
    let rec_dense = mk(false).run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = Gd::plain(8, 48, 0.1);
    let rec_sparse = mk(true).run(&mut b, &q, &x0, &opts).unwrap();
    assert_records_bitwise_eq(&rec_dense, &rec_sparse, "tree hub compression sparse vs dense");
    assert_eq!(rec_dense.edge_bits_up, rec_sparse.edge_bits_up);
}

/// The hub-sharded worker pool visits results in cohort order, so a
/// pool-parallel tree run is bit-identical to the serial tree run.
#[test]
fn tree_parallel_run_matches_serial() {
    let q = quadratic(76, 12, 32);
    let x0 = vec![1.0f32; 32];
    let opts = RunOptions { rounds: 50, eval_every: 10, seed: 6, ..Default::default() };
    let mk = || {
        Driver::new()
            .with_sampler(Box::new(NiceSampling { n: 12, tau: 6 }))
            .with_up(Box::new(fedeff::compress::topk::TopK::new(4)))
            .with_up_edge(1, Box::new(fedeff::compress::topk::TopK::new(8)))
            .with_topology(Topology::Tree(AggTree::even(12, &[3], vec![0.05, 1.0])))
    };
    let mut a = Gd::plain(12, 32, 0.1);
    let rec_s = mk().run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = Gd::plain(12, 32, 0.1);
    let rec_p = mk().run_parallel(&mut b, &q, &x0, &opts).unwrap();
    assert_records_bitwise_eq(&rec_s, &rec_p, "tree serial vs hub-sharded pool");
    assert_eq!(rec_s.edge_bits_up, rec_p.edge_bits_up);
}

/// Scaffold's two uplink messages route as independent channels, so hub
/// re-compression keeps model and control partials separate and the
/// algorithm still converges.
#[test]
fn tree_scaffold_channels_converge_under_hub_compression() {
    let q = quadratic(77, 8, 24);
    let x0 = vec![2.0f32; 24];
    let opts = RunOptions { rounds: 300, eval_every: 300, ..Default::default() };
    let mut alg = fedeff::algorithms::scaffold::Scaffold::new(3, 0.05);
    let rec = Driver::new()
        .with_up_edge(1, Box::new(fedeff::compress::topk::TopK::new(18)))
        .with_topology(Topology::Tree(AggTree::even(8, &[2], vec![0.05, 1.0])))
        .run(&mut alg, &q, &x0, &opts)
        .unwrap();
    let first = rec.rounds.first().unwrap().loss;
    let last = rec.last().unwrap().loss;
    assert!(last.is_finite() && last < first, "{first} -> {last}");
    // the hub edge really re-compressed: it carried bits, fewer than the
    // dense leaf edge's
    assert!(rec.edge_bits_up[1] > 0);
    assert!(rec.edge_bits_up[1] < rec.edge_bits_up[0]);
}

/// A middle pass-through level relays exactly what it receives: with
/// only the top edge compressed, edge 1 carries the same bits as the
/// leaf edge and edge 2 carries the re-compressed partials.
#[test]
fn tree_pass_through_levels_relay_bits() {
    let q = quadratic(78, 8, 32);
    let x0 = vec![1.0f32; 32];
    let opts = RunOptions { rounds: 10, eval_every: 10, ..Default::default() };
    let mut alg = Gd::plain(8, 32, 0.1);
    let rec = Driver::new()
        .with_up_edge(2, Box::new(fedeff::compress::topk::TopK::new(16)))
        .with_topology(Topology::Tree(AggTree::even(8, &[4, 2], vec![0.05, 0.2, 1.0])))
        .run(&mut alg, &q, &x0, &opts)
        .unwrap();
    assert_eq!(rec.edge_bits_up.len(), 3);
    assert_eq!(rec.edge_bits_up[1], rec.edge_bits_up[0], "pass-through relay");
    assert!(rec.edge_bits_up[2] > 0);
    // 2 hubs send Top-K(16) partials instead of 8 dense client messages
    assert!(rec.edge_bits_up[2] < rec.edge_bits_up[1]);
}

/// Acceptance pin: a TOML-only config runs FedAvg over a 3-level tree
/// with Top-K client→hub and QSGD hub→server, and the ledger books
/// strictly fewer hub→server bits than the flat run of the same
/// experiment books at its (only) server-facing edge.
#[test]
fn toml_tree_fedavg_topk_qsgd_reduces_root_bits() {
    let toml = r#"
[experiment]
name = "tree-e2e"
rounds = 8
seed = 2

[dataset]
clients = 12

[algorithm]
kind = "fedavg"
local_steps = 2
lr = 0.1
sampler = "full"

[topology]
levels = 3
hubs = 3
c1 = 0.05
c2 = 1.0

[links.up.l0]
kind = "top-k"
k = 6

[links.up.l1]
kind = "qsgd"
k = 4
"#;
    let d = 64usize;
    let q = quadratic(80, 12, d);
    let opts = RunOptions { rounds: 8, eval_every: 8, seed: 2, ..Default::default() };

    let spec = fedeff::config::Spec::parse(toml).unwrap();
    let mut alg = build_algorithm(&spec.algorithm, &q).unwrap();
    let driver = fedeff::config::build_driver(&spec, 12).unwrap();
    let rec_tree = driver.run(alg.as_mut(), &q, &vec![1.0; d], &opts).unwrap();
    assert!(rec_tree.last().unwrap().loss.is_finite());

    // flat run of the same experiment: same Top-K uplink, no hierarchy
    let leaf_as_link = "[links.up.l0]\nkind = \"top-k\"\nk = 6\n";
    let leaf_as_compressor = "[compressor]\nup = \"top-k\"\nk = 6\n";
    let flat_toml = toml
        .replace("[topology]\nlevels = 3\nhubs = 3\nc1 = 0.05\nc2 = 1.0\n", "")
        .replace(leaf_as_link, leaf_as_compressor)
        .replace("[links.up.l1]\nkind = \"qsgd\"\nk = 4\n", "");
    let spec_flat = fedeff::config::Spec::parse(&flat_toml).unwrap();
    assert!(spec_flat.topology.is_none(), "flat spec still has a topology");
    let mut alg_flat = build_algorithm(&spec_flat.algorithm, &q).unwrap();
    let driver_flat = fedeff::config::build_driver(&spec_flat, 12).unwrap();
    let rec_flat = driver_flat.run(alg_flat.as_mut(), &q, &vec![1.0; d], &opts).unwrap();

    // flat: all 12 clients' Top-K messages hit the server every round
    let flat_server_bits = 12 * sparse_bits(6, d) * 8;
    assert_eq!(rec_tree.edge_bits_up.len(), 2);
    assert_eq!(rec_tree.edge_bits_up[0], flat_server_bits, "leaf edge is the same Top-K");
    assert!(
        rec_tree.edge_bits_up[1] < flat_server_bits,
        "hub→server must book strictly fewer bits: {} vs flat {}",
        rec_tree.edge_bits_up[1],
        flat_server_bits
    );
    // the flat run's per-node uplink is exactly the Top-K message size
    // per round — the same leaf compression the tree run applied
    assert_eq!(rec_flat.last().unwrap().bits_up, sparse_bits(6, d) * 8);
}

// ---------------------------------------------------------------------------
// Fused uplink pipeline (in-worker compress + O(k) driver merge)
// ---------------------------------------------------------------------------

/// Run the same experiment three ways — fully serial, reference pool
/// (`with_fused_uplink(false)`), and fused pool — and pin all three
/// bit-for-bit equal, per-edge ledger included. Per-client compression
/// streams make the draws execution-order-free, so this holds *by
/// construction*; the assert keeps it that way.
fn pin_fused_reference(
    what: &str,
    q: &QuadraticOracle,
    x0: &[f32],
    opts: &RunOptions,
    mk_drv: &dyn Fn() -> Driver,
    mk_alg: &dyn Fn() -> Box<dyn fedeff::algorithms::FlAlgorithm>,
) {
    let mut a = mk_alg();
    let rec_serial = mk_drv().run(a.as_mut(), q, x0, opts).unwrap();
    let mut b = mk_alg();
    let rec_fused = mk_drv().run_parallel(b.as_mut(), q, x0, opts).unwrap();
    let mut c = mk_alg();
    let rec_ref = mk_drv().with_fused_uplink(false).run_parallel(c.as_mut(), q, x0, opts).unwrap();
    assert_records_bitwise_eq(&rec_fused, &rec_serial, &format!("{what}: fused vs serial"));
    assert_records_bitwise_eq(&rec_fused, &rec_ref, &format!("{what}: fused vs reference pool"));
    assert_eq!(rec_fused.edge_bits_up, rec_serial.edge_bits_up, "{what}: edge ledger vs serial");
    assert_eq!(rec_fused.edge_bits_up, rec_ref.edge_bits_up, "{what}: edge ledger vs reference");
}

fn spec_alg(kind: &str) -> fedeff::config::AlgorithmSpec {
    fedeff::config::AlgorithmSpec { kind: kind.to_string(), k: Some(2), ..Default::default() }
}

/// Fused == reference == serial for every plan-capable algorithm on a
/// flat topology with a Top-K uplink and cohort sampling (Scafflix
/// rejects samplers, so it runs full-participation — its conditional
/// plan keeps it on the reference path, pinned trivially equal).
#[test]
fn fused_matches_reference_flat_sampled() {
    let q = quadratic(85, 10, 48);
    let x0 = vec![1.0f32; 48];
    let opts = RunOptions { rounds: 60, eval_every: 15, seed: 11, ..Default::default() };
    for kind in ["gd", "fedavg", "fedprox", "scaffold"] {
        pin_fused_reference(
            &format!("flat+sampled {kind}"),
            &q,
            &x0,
            &opts,
            &|| {
                Driver::new()
                    .with_sampler(Box::new(NiceSampling { n: 10, tau: 5 }))
                    .with_up(Box::new(fedeff::compress::topk::TopK::new(6)))
            },
            &|| build_algorithm(&spec_alg(kind), &q).unwrap(),
        );
    }
    pin_fused_reference(
        "flat scafflix (conditional plan declines fusing)",
        &q,
        &x0,
        &opts,
        &|| Driver::new().with_up(Box::new(fedeff::compress::topk::TopK::new(6))),
        &|| build_algorithm(&spec_alg("scafflix"), &q).unwrap(),
    );
}

/// Fused == reference == serial over an executed 3-level tree with
/// hub re-compression (leaf Top-K, hub Top-K), sampled cohorts, for
/// every tree-routing plan-capable algorithm — Scaffold's two channels
/// keep distinct hub partials in both paths.
#[test]
fn fused_matches_reference_3level_tree() {
    let q = quadratic(86, 12, 40);
    let x0 = vec![1.5f32; 40];
    let opts = RunOptions { rounds: 50, eval_every: 10, seed: 7, ..Default::default() };
    for kind in ["gd", "fedavg", "fedprox", "scaffold"] {
        pin_fused_reference(
            &format!("3-level tree {kind}"),
            &q,
            &x0,
            &opts,
            &|| {
                Driver::new()
                    .with_sampler(Box::new(NiceSampling { n: 12, tau: 6 }))
                    .with_up(Box::new(fedeff::compress::topk::TopK::new(5)))
                    .with_up_edge(1, Box::new(fedeff::compress::topk::TopK::new(10)))
                    .with_topology(Topology::Tree(AggTree::even(12, &[3], vec![0.05, 1.0])))
            },
            &|| build_algorithm(&spec_alg(kind), &q).unwrap(),
        );
    }
}

/// The satellite composition: Rand-K uplink + cohort sampling +
/// 3-level tree + 50% global mask, fused vs reference vs serial —
/// randomized compression draws, support-gathered payloads and hub
/// flushes all line up bit-for-bit.
#[test]
fn fused_matches_reference_randk_sampled_tree_masked() {
    use fedeff::pruning::Method;
    use fedeff::sparsity::MaskSpec;
    let q = quadratic(87, 12, 64);
    let x0 = vec![1.0f32; 64];
    let opts = RunOptions { rounds: 40, eval_every: 10, seed: 3, ..Default::default() };
    let mask = || MaskSpec {
        method: Method::SymWanda { alpha: 0.5 },
        sparsity: 0.5,
        ..MaskSpec::default()
    };
    for kind in ["gd", "fedavg", "scaffold"] {
        pin_fused_reference(
            &format!("randk+sampled+tree+mask {kind}"),
            &q,
            &x0,
            &opts,
            &|| {
                Driver::new()
                    .with_sampler(Box::new(NiceSampling { n: 12, tau: 6 }))
                    .with_up(Box::new(fedeff::compress::randk::RandK::unbiased(6)))
                    .with_up_edge(1, Box::new(fedeff::compress::randk::RandK::unbiased(12)))
                    .with_topology(Topology::Tree(AggTree::even(12, &[4], vec![0.05, 1.0])))
                    .with_mask(mask())
            },
            &|| build_algorithm(&spec_alg(kind), &q).unwrap(),
        );
    }
}

/// Masked runs with *no* compressor fuse too (raw support payloads are
/// already the sparse wire format), flat and personalized-vs-global:
/// personalized masks stay on the reference path and still match.
#[test]
fn fused_matches_reference_masked_no_compressor() {
    use fedeff::pruning::Method;
    use fedeff::sparsity::MaskSpec;
    let q = quadratic(88, 8, 32);
    let x0 = vec![2.0f32; 32];
    let opts = RunOptions { rounds: 40, eval_every: 10, seed: 9, ..Default::default() };
    let mask = |personalized: bool| MaskSpec {
        method: Method::SymWanda { alpha: 0.5 },
        sparsity: 0.5,
        personalized,
        ..MaskSpec::default()
    };
    for kind in ["gd", "fedavg", "fedprox", "scaffold"] {
        pin_fused_reference(
            &format!("masked no-comp {kind}"),
            &q,
            &x0,
            &opts,
            &|| Driver::new().with_mask(mask(false)),
            &|| build_algorithm(&spec_alg(kind), &q).unwrap(),
        );
    }
    // personalized masks are declined by the fused path (per-client
    // supports in the workers would leak across rows) — the three
    // execution modes must still agree because they all take the
    // reference path
    pin_fused_reference(
        "masked personalized fedavg (reference path)",
        &q,
        &x0,
        &opts,
        &|| Driver::new().with_mask(mask(true)),
        &|| build_algorithm(&spec_alg("fedavg"), &q).unwrap(),
    );
}

/// Every registry algorithm runs over a multi-level tree straight from
/// TOML (tree-routing algorithms aggregate hub-by-hub; the rest see
/// leaf compression plus the per-edge cost model).
#[test]
fn registry_every_name_runs_over_a_tree_from_toml() {
    let q = quadratic(81, 6, 16);
    for name in registry() {
        let toml = format!(
            "[experiment]\nname = \"reg-tree\"\n[dataset]\nclients = 6\n[algorithm]\nkind = \"{name}\"\nk = 2\n[topology]\nlevels = 3\nhubs = 2\n[links.up.l1]\nkind = \"top-k\"\nk = 8\n"
        );
        let spec = fedeff::config::Spec::parse(&toml).unwrap();
        let mut alg = build_algorithm(&spec.algorithm, &q)
            .unwrap_or_else(|e| panic!("{name} failed to build: {e}"));
        let driver = fedeff::config::build_driver(&spec, 6)
            .unwrap_or_else(|e| panic!("{name} failed to build driver: {e}"));
        let opts = RunOptions { rounds: 2, eval_every: 1, ..Default::default() };
        let rec = driver
            .run(alg.as_mut(), &q, &vec![1.0; 16], &opts)
            .unwrap_or_else(|e| panic!("{name} failed to run over a tree: {e}"));
        assert!(rec.last().unwrap().loss.is_finite(), "{name}: non-finite loss over tree");
    }
}
