//! The redesign's safety net: the `Driver`-based algorithms must
//! reproduce the seed (pre-driver) hand-rolled loops *bit-for-bit* on the
//! quadratic oracle at fixed seeds. The reference loops below are verbatim
//! copies of the seed implementations of GD, FedAvg and Scafflix.
//!
//! Also covers the registry (every advertised name constructs and runs),
//! the two previously-impossible compositions the redesign opens
//! (Scafflix with Top-K uplink compression and FedAvg costed over a
//! 2-level hierarchy — both reachable from a TOML spec), and the sparse
//! message fast path: runs over the O(k) sparse link path must match the
//! dense reference path bit-for-bit in loss and booked bits.

use fedeff::algorithms::gd::{FlixGd, Gd};
use fedeff::algorithms::scafflix::Scafflix;
use fedeff::algorithms::{build_algorithm, registry, RunOptions};
use fedeff::coordinator::driver::{Driver, Topology};
use fedeff::coordinator::hierarchy::Hierarchy;
use fedeff::metrics::RunRecord;
use fedeff::oracle::quadratic::QuadraticOracle;
use fedeff::oracle::{solve_local, Oracle};
use fedeff::sampling::{CohortSampler, NiceSampling};
use fedeff::vecmath as vm;

type Series = Vec<(f32, Option<f32>)>;

fn series_of(rec: &RunRecord) -> Series {
    rec.rounds.iter().map(|r| (r.loss, r.gap)).collect()
}

fn assert_series_eq(driver: &Series, seed: &Series, what: &str) {
    assert_eq!(driver.len(), seed.len(), "{what}: series lengths differ");
    for (i, (d, s)) in driver.iter().zip(seed).enumerate() {
        assert!(
            d.0 == s.0 && d.1 == s.1,
            "{what}: entry {i} differs: driver {d:?} vs seed {s:?}"
        );
    }
}

fn erm_eval(q: &QuadraticOracle, x: &[f32], opts: &RunOptions) -> (f32, Option<f32>) {
    let mut g = vec![0.0f32; q.dim()];
    let loss = q.full_loss_grad(x, &mut g).unwrap();
    let gap = match (opts.f_star, &opts.x_star) {
        (Some(fs), _) => Some(loss - fs),
        (None, Some(xs)) => Some(vm::dist_sq(x, xs)),
        _ => None,
    };
    (loss, gap)
}

/// Verbatim copy of the seed `FlixGd::run` loop (loss/gap series only).
fn seed_gd_series(flix: &FlixGd, q: &QuadraticOracle, x0: &[f32], opts: &RunOptions) -> Series {
    let d = q.dim();
    let mut x = x0.to_vec();
    let mut g = vec![0.0f32; d];
    let mut out = Vec::new();
    for t in 0..opts.rounds {
        let loss = flix.flix_loss_grad(q, &x, &mut g).unwrap();
        if t % opts.eval_every == 0 {
            out.push((loss, opts.f_star.map(|fs| loss - fs)));
        }
        vm::axpy(-flix.gamma, &g, &mut x);
    }
    // seed final record: ERM record_eval, loss/gap then fixed to FLIX
    let loss = flix.flix_loss(q, &x).unwrap();
    out.push((loss, opts.f_star.map(|fs| loss - fs)));
    out
}

/// Verbatim copy of the seed `FedAvg::run` loop.
#[allow(clippy::too_many_arguments)]
fn seed_fedavg_series(
    q: &QuadraticOracle,
    sampler: &NiceSampling,
    local_steps: usize,
    lr: f32,
    dropout: f32,
    x0: &[f32],
    opts: &RunOptions,
) -> Series {
    let d = q.dim();
    let mut rng = fedeff::rng(opts.seed);
    let mut x = x0.to_vec();
    let mut g = vec![0.0f32; d];
    let mut xi = vec![0.0f32; d];
    let mut next = vec![0.0f32; d];
    let mut out = Vec::new();
    for t in 0..opts.rounds {
        if t % opts.eval_every == 0 {
            out.push(erm_eval(q, &x, opts));
        }
        let mut cohort = sampler.sample(&mut rng);
        if dropout > 0.0 {
            cohort.retain(|_| !rng.bernoulli(dropout));
        }
        if cohort.is_empty() {
            continue; // wasted round: every sampled client dropped
        }
        next.fill(0.0);
        for &i in &cohort {
            xi.copy_from_slice(&x);
            for _ in 0..local_steps {
                q.loss_grad(i, &xi, &mut g).unwrap();
                vm::axpy(-lr, &g, &mut xi);
            }
            vm::acc_mean(&xi, cohort.len() as f32, &mut next);
        }
        x.copy_from_slice(&next);
    }
    out.push(erm_eval(q, &x, opts));
    out
}

/// Verbatim copy of the seed `Scafflix::run` loop.
#[allow(clippy::too_many_arguments)]
fn seed_scafflix_series(
    q: &QuadraticOracle,
    alphas: &[f32],
    x_stars: &[Vec<f32>],
    gammas: &[f32],
    p: f32,
    clients_per_round: Option<usize>,
    x0: &[f32],
    opts: &RunOptions,
) -> Series {
    fn flixify(alphas: &[f32], x_stars: &[Vec<f32>], i: usize, x: &[f32], out: &mut [f32]) {
        let a = alphas[i];
        for j in 0..x.len() {
            out[j] = a * x[j] + (1.0 - a) * x_stars[i][j];
        }
    }
    let d = q.dim();
    let n = q.n_clients();
    let flix = FlixGd { alphas: alphas.to_vec(), x_stars: x_stars.to_vec(), gamma: 0.0 };
    let gamma_srv = 1.0
        / ((0..n).map(|i| alphas[i] * alphas[i] / gammas[i]).sum::<f32>() / n as f32);
    let mut rng = fedeff::rng(opts.seed);
    let mut x_i = vec![x0.to_vec(); n];
    let mut h_i = vec![vec![0.0f32; d]; n];
    let mut hat = vec![vec![0.0f32; d]; n];
    let mut tilde = vec![0.0f32; d];
    let mut g = vec![0.0f32; d];
    let mut xbar = vec![0.0f32; d];
    let mut out = Vec::new();

    for t in 0..opts.rounds {
        if t % opts.eval_every == 0 {
            xbar.fill(0.0);
            for xi in &x_i {
                vm::acc_mean(xi, n as f32, &mut xbar);
            }
            let loss = flix.flix_loss(q, &xbar).unwrap();
            out.push((loss, opts.f_star.map(|fs| loss - fs)));
        }
        for i in 0..n {
            flixify(alphas, x_stars, i, &x_i[i], &mut tilde);
            q.loss_grad(i, &tilde, &mut g).unwrap();
            let step = gammas[i] / alphas[i].max(1e-8);
            for j in 0..d {
                hat[i][j] = x_i[i][j] - step * (g[j] - h_i[i][j]);
            }
        }
        if rng.f32_unit() < p {
            let participants: Vec<usize> = match clients_per_round {
                None => (0..n).collect(),
                Some(tau) => {
                    let mut idx: Vec<usize> = (0..n).collect();
                    rng.shuffle(&mut idx);
                    idx.truncate(tau.min(n));
                    idx
                }
            };
            let norm = participants.len() as f32;
            xbar.fill(0.0);
            for &jc in &participants {
                let w = gamma_srv * alphas[jc] * alphas[jc] / gammas[jc] / norm;
                vm::axpy(w, &hat[jc], &mut xbar);
            }
            for &i in &participants {
                let coef = p * alphas[i] / gammas[i];
                for j in 0..d {
                    h_i[i][j] += coef * (xbar[j] - hat[i][j]);
                }
                x_i[i].copy_from_slice(&xbar);
            }
            for i in 0..n {
                if !participants.contains(&i) {
                    x_i[i].copy_from_slice(&hat[i]);
                }
            }
        } else {
            for i in 0..n {
                x_i[i].copy_from_slice(&hat[i]);
            }
        }
    }
    xbar.fill(0.0);
    for xi in &x_i {
        vm::acc_mean(xi, n as f32, &mut xbar);
    }
    let loss = flix.flix_loss(q, &xbar).unwrap();
    out.push((loss, opts.f_star.map(|fs| loss - fs)));
    out
}

fn quadratic(seed: u64, n: usize, d: usize) -> QuadraticOracle {
    let mut rng = fedeff::rng(seed);
    QuadraticOracle::random(n, d, 0.5, 2.0, 1.0, &mut rng)
}

#[test]
fn driver_gd_matches_seed_loop_plain() {
    let q = quadratic(27, 4, 6);
    let xs = q.minimizer();
    let fs = q.full_loss(&xs).unwrap();
    let x0 = vec![1.0f32; 6];
    let opts =
        RunOptions { rounds: 120, eval_every: 10, f_star: Some(fs), seed: 7, ..Default::default() };
    let flix = FlixGd::plain(4, 6, 0.4);
    let expected = seed_gd_series(&flix, &q, &x0, &opts);
    let mut alg = Gd::new(flix);
    let rec = Driver::new().run(&mut alg, &q, &x0, &opts).unwrap();
    assert_series_eq(&series_of(&rec), &expected, "plain GD");
}

#[test]
fn driver_gd_matches_seed_loop_personalized() {
    let q = quadratic(28, 5, 7);
    let x_stars: Vec<Vec<f32>> = (0..5)
        .map(|i| solve_local(&q, i, &vec![0.0; 7], 0.3, 600, 1e-7).unwrap())
        .collect();
    let flix = FlixGd { alphas: vec![0.5; 5], x_stars, gamma: 0.3 };
    let x0 = vec![2.0f32; 7];
    let opts = RunOptions { rounds: 90, eval_every: 15, seed: 11, ..Default::default() };
    let expected = seed_gd_series(&flix, &q, &x0, &opts);
    let mut alg = Gd::new(flix.clone());
    let rec = Driver::new().run(&mut alg, &q, &x0, &opts).unwrap();
    assert_series_eq(&series_of(&rec), &expected, "personalized GD");
}

#[test]
fn driver_fedavg_matches_seed_loop() {
    let q = quadratic(33, 6, 6);
    let xs = q.minimizer();
    let x0 = vec![3.0f32; 6];
    let opts = RunOptions {
        rounds: 150,
        eval_every: 10,
        x_star: Some(xs),
        seed: 4,
        ..Default::default()
    };
    let sampler = NiceSampling { n: 6, tau: 3 };
    let expected = seed_fedavg_series(&q, &sampler, 5, 0.1, 0.0, &x0, &opts);
    let mut alg = fedeff::algorithms::fedavg::FedAvg::new(5, 0.1);
    let drv = Driver::new().with_sampler(Box::new(NiceSampling { n: 6, tau: 3 }));
    let rec = drv.run(&mut alg, &q, &x0, &opts).unwrap();
    assert_series_eq(&series_of(&rec), &expected, "FedAvg");
}

#[test]
fn driver_fedavg_matches_seed_loop_with_dropout() {
    let q = quadratic(35, 6, 5);
    let xs = q.minimizer();
    let fs = q.full_loss(&xs).unwrap();
    let x0 = vec![2.0f32; 5];
    let opts = RunOptions {
        rounds: 200,
        eval_every: 25,
        f_star: Some(fs),
        seed: 9,
        ..Default::default()
    };
    let sampler = NiceSampling { n: 6, tau: 3 };
    let expected = seed_fedavg_series(&q, &sampler, 2, 0.2, 0.5, &x0, &opts);
    let mut alg = fedeff::algorithms::fedavg::FedAvg::new(2, 0.2);
    alg.dropout = 0.5;
    let drv = Driver::new().with_sampler(Box::new(NiceSampling { n: 6, tau: 3 }));
    let rec = drv.run(&mut alg, &q, &x0, &opts).unwrap();
    assert_series_eq(&series_of(&rec), &expected, "FedAvg+dropout");
}

#[test]
fn driver_scafflix_matches_seed_loop() {
    let q = quadratic(31, 6, 8);
    let x_stars: Vec<Vec<f32>> = (0..6)
        .map(|i| solve_local(&q, i, &vec![0.0; 8], 0.3, 800, 1e-8).unwrap())
        .collect();
    let gammas: Vec<f32> = (0..6).map(|i| 1.0 / q.smoothness(i)).collect();
    let alphas = vec![0.5f32; 6];
    let x0 = vec![1.0f32; 8];
    let opts = RunOptions { rounds: 200, eval_every: 20, seed: 2, ..Default::default() };
    let expected =
        seed_scafflix_series(&q, &alphas, &x_stars, &gammas, 0.3, None, &x0, &opts);
    let mut alg = Scafflix::standard(&q, 0.5, 0.3, x_stars);
    let rec = Driver::new().run(&mut alg, &q, &x0, &opts).unwrap();
    assert_series_eq(&series_of(&rec), &expected, "Scafflix");
}

#[test]
fn driver_scafflix_matches_seed_loop_partial_participation() {
    let q = quadratic(32, 6, 8);
    let x_stars: Vec<Vec<f32>> = (0..6)
        .map(|i| solve_local(&q, i, &vec![0.0; 8], 0.3, 800, 1e-8).unwrap())
        .collect();
    let gammas: Vec<f32> = (0..6).map(|i| 1.0 / q.smoothness(i)).collect();
    let alphas = vec![0.5f32; 6];
    let x0 = vec![1.0f32; 8];
    let opts = RunOptions { rounds: 250, eval_every: 50, seed: 4, ..Default::default() };
    let expected =
        seed_scafflix_series(&q, &alphas, &x_stars, &gammas, 0.5, Some(3), &x0, &opts);
    let mut alg = Scafflix::standard(&q, 0.5, 0.5, x_stars);
    alg.clients_per_round = Some(3);
    let rec = Driver::new().run(&mut alg, &q, &x0, &opts).unwrap();
    assert_series_eq(&series_of(&rec), &expected, "Scafflix partial");
}

#[test]
fn registry_every_name_constructs_and_runs() {
    let q = quadratic(99, 4, 6);
    for name in registry() {
        let spec = fedeff::config::AlgorithmSpec {
            kind: name.to_string(),
            k: Some(2),
            ..Default::default()
        };
        let mut alg = build_algorithm(&spec, &q)
            .unwrap_or_else(|e| panic!("registry name {name} failed to build: {e}"));
        let opts = RunOptions { rounds: 2, eval_every: 1, ..Default::default() };
        let rec = Driver::new()
            .run(alg.as_mut(), &q, &vec![1.0; 6], &opts)
            .unwrap_or_else(|e| panic!("registry name {name} failed to run: {e}"));
        assert_eq!(rec.rounds.len(), 3, "{name}: expected evals at t=0,1 and final");
        assert!(rec.last().unwrap().loss.is_finite(), "{name}: non-finite loss");
    }
}

#[test]
fn composition_scafflix_with_topk_uplink() {
    // previously impossible: the seed Scafflix had no compressor slot
    let q = quadratic(41, 6, 8);
    let x_stars: Vec<Vec<f32>> = (0..6)
        .map(|i| solve_local(&q, i, &vec![0.0; 8], 0.3, 800, 1e-8).unwrap())
        .collect();
    let mut alg = Scafflix::standard(&q, 0.5, 0.3, x_stars);
    let opts = RunOptions { rounds: 400, eval_every: 400, seed: 6, ..Default::default() };
    let drv = Driver::new().with_up(Box::new(fedeff::compress::topk::TopK::new(4)));
    let rec = drv.run(&mut alg, &q, &vec![2.0; 8], &opts).unwrap();
    let first = rec.rounds.first().unwrap().loss;
    let last = rec.last().unwrap().loss;
    assert!(last.is_finite() && last < first, "compressed Scafflix: {first} -> {last}");
    // compressed uplink books fewer bits than the dense downlink
    let r = rec.last().unwrap();
    assert!(r.bits_up < r.bits_down, "up {} vs down {}", r.bits_up, r.bits_down);
}

#[test]
fn composition_fedavg_over_hierarchy() {
    // previously impossible: the seed FedAvg only had a scalar cost knob,
    // now any algorithm runs over a 2-level topology via the driver
    let q = quadratic(42, 6, 5);
    let mut alg = fedeff::algorithms::fedavg::FedAvg::new(3, 0.1);
    let opts = RunOptions { rounds: 20, eval_every: 20, ..Default::default() };
    let drv = Driver::new()
        .with_sampler(Box::new(NiceSampling { n: 6, tau: 3 }))
        .with_topology(Topology::Hier(Hierarchy::even(6, 2, 0.05, 1.0)));
    let rec = drv.run(&mut alg, &q, &vec![1.0; 5], &opts).unwrap();
    let cost = rec.last().unwrap().comm_cost;
    assert!((cost - 20.0 * 1.05).abs() < 1e-9, "hierarchical cost {cost}");
}

/// Assert two records are bit-for-bit identical in loss and in the
/// cumulative per-node bits on both links.
fn assert_records_bitwise_eq(
    a: &fedeff::metrics::RunRecord,
    b: &fedeff::metrics::RunRecord,
    what: &str,
) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: record lengths differ");
    for (i, (ra, rb)) in a.rounds.iter().zip(&b.rounds).enumerate() {
        assert!(ra.loss == rb.loss, "{what}: entry {i} loss {} vs {}", ra.loss, rb.loss);
        assert_eq!(ra.bits_up, rb.bits_up, "{what}: entry {i} bits_up");
        assert_eq!(ra.bits_down, rb.bits_down, "{what}: entry {i} bits_down");
    }
}

#[test]
fn sparse_path_matches_dense_gd_topk() {
    let q = quadratic(60, 6, 64);
    let x0 = vec![1.0f32; 64];
    let opts = RunOptions { rounds: 80, eval_every: 10, seed: 3, ..Default::default() };
    let mut a = Gd::plain(6, 64, 0.1);
    let rec_dense = Driver::new()
        .with_up(Box::new(fedeff::compress::topk::TopK::new(8)))
        .with_sparse_links(false)
        .run(&mut a, &q, &x0, &opts)
        .unwrap();
    let mut b = Gd::plain(6, 64, 0.1);
    let rec_sparse = Driver::new()
        .with_up(Box::new(fedeff::compress::topk::TopK::new(8)))
        .run(&mut b, &q, &x0, &opts)
        .unwrap();
    assert_records_bitwise_eq(&rec_dense, &rec_sparse, "GD+TopK");
    // the compressed uplink actually booked sparse-message bits
    let dense_bits = 32u64 * 64 * 80;
    assert!(rec_sparse.last().unwrap().bits_up < dense_bits);
}

#[test]
fn sparse_path_matches_dense_ef21_topk() {
    let q = quadratic(61, 8, 48);
    let x0 = vec![1.0f32; 48];
    let opts = RunOptions { rounds: 120, eval_every: 20, seed: 8, ..Default::default() };
    let mut a =
        fedeff::algorithms::efbv::EfBv::ef21(Box::new(fedeff::compress::topk::TopK::new(6)));
    let rec_dense = Driver::new()
        .with_sparse_links(false)
        .run(&mut a, &q, &x0, &opts)
        .unwrap();
    let mut b =
        fedeff::algorithms::efbv::EfBv::ef21(Box::new(fedeff::compress::topk::TopK::new(6)));
    let rec_sparse = Driver::new().run(&mut b, &q, &x0, &opts).unwrap();
    assert_records_bitwise_eq(&rec_dense, &rec_sparse, "EF21+TopK");
}

#[test]
fn sparse_path_matches_dense_fedavg_randk() {
    // FedCOM delta compression on both links under partial participation:
    // Rand-K draws from the link RNG, which both paths must consume
    // identically
    let q = quadratic(62, 8, 32);
    let x0 = vec![2.0f32; 32];
    let opts = RunOptions { rounds: 100, eval_every: 20, seed: 13, ..Default::default() };
    let mk = |sparse: bool| {
        Driver::new()
            .with_sampler(Box::new(NiceSampling { n: 8, tau: 4 }))
            .with_up(Box::new(fedeff::compress::randk::RandK::scaled(5)))
            .with_down(Box::new(fedeff::compress::randk::RandK::scaled(5)))
            .with_sparse_links(sparse)
    };
    let mut a = fedeff::algorithms::fedavg::FedAvg::new(3, 0.1);
    let rec_dense = mk(false).run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = fedeff::algorithms::fedavg::FedAvg::new(3, 0.1);
    let rec_sparse = mk(true).run(&mut b, &q, &x0, &opts).unwrap();
    assert_records_bitwise_eq(&rec_dense, &rec_sparse, "FedAvg+RandK");
}

#[test]
fn sparse_path_matches_dense_scaffold_topk() {
    let q = quadratic(63, 6, 40);
    let x0 = vec![1.5f32; 40];
    let opts = RunOptions { rounds: 100, eval_every: 25, seed: 17, ..Default::default() };
    let mk = |sparse: bool| {
        Driver::new()
            .with_sampler(Box::new(NiceSampling { n: 6, tau: 3 }))
            .with_up(Box::new(fedeff::compress::topk::TopK::new(5)))
            .with_sparse_links(sparse)
    };
    let mut a = fedeff::algorithms::scaffold::Scaffold::new(3, 0.05);
    let rec_dense = mk(false).run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = fedeff::algorithms::scaffold::Scaffold::new(3, 0.05);
    let rec_sparse = mk(true).run(&mut b, &q, &x0, &opts).unwrap();
    assert_records_bitwise_eq(&rec_dense, &rec_sparse, "Scaffold+TopK");
}

#[test]
fn toml_spec_drives_registry_and_compositions() {
    // end-to-end: TOML -> Spec -> registry build + driver build -> run
    let toml = r#"
[experiment]
name = "compose-e2e"
rounds = 4

[dataset]
clients = 4

[algorithm]
kind = "scafflix"
alpha = 0.5
p = 0.5

[compressor]
up = "top-k"
k = 3

[topology]
hubs = 2
c1 = 0.05
c2 = 1.0
"#;
    let spec = fedeff::config::Spec::parse(toml).unwrap();
    let q = quadratic(50, 4, 6);
    let mut alg = build_algorithm(&spec.algorithm, &q).unwrap();
    let driver = fedeff::config::build_driver(&spec, 4).unwrap();
    let opts = RunOptions {
        rounds: spec.experiment.rounds,
        eval_every: spec.experiment.eval_every,
        seed: spec.experiment.seed,
        ..Default::default()
    };
    let rec = driver.run(alg.as_mut(), &q, &vec![1.0; 6], &opts).unwrap();
    assert!(rec.last().unwrap().loss.is_finite());

    // and a second composition from TOML: fedavg over the same hierarchy
    let toml2 = toml
        .replace("kind = \"scafflix\"", "kind = \"fedavg\"")
        .replace("alpha = 0.5", "local_steps = 2")
        .replace("p = 0.5", "lr = 0.1");
    let spec2 = fedeff::config::Spec::parse(&toml2).unwrap();
    let mut alg2 = build_algorithm(&spec2.algorithm, &q).unwrap();
    let driver2 = fedeff::config::build_driver(&spec2, 4).unwrap();
    let rec2 = driver2.run(alg2.as_mut(), &q, &vec![1.0; 6], &opts).unwrap();
    assert!(rec2.last().unwrap().loss.is_finite());
    // hierarchy pricing applied: fedavg communicates every round
    assert!((rec2.last().unwrap().comm_cost - 4.0 * 1.05).abs() < 1e-9);
}
