//! Integration: the HLO artifacts (lowered from JAX + Pallas) must agree
//! numerically with the independent pure-Rust reference implementations.
//! This is the end-to-end correctness bridge between the three layers.
//!
//! Skipped gracefully (with a loud message) when `artifacts/` is missing.

use std::rc::Rc;

use fedeff::data::synth::{logreg_dataset, Heterogeneity};
use fedeff::oracle::hlo::{HloLm, HloLogReg, HloMlp};
use fedeff::oracle::logreg_rs::RustLogReg;
use fedeff::oracle::Oracle;
use fedeff::runtime::Runtime;

fn runtime() -> Option<Rc<Runtime>> {
    match Runtime::from_default_manifest() {
        Ok(rt) => Some(Rc::new(rt)),
        Err(e) => {
            eprintln!("SKIP (no artifacts: {e}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn logreg_hlo_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    let mut rng = fedeff::rng(100);
    let data = logreg_dataset(112, 256, 4, Heterogeneity::FeatureShift(0.5), 0.3, &mut rng);
    let hlo = HloLogReg::new(rt, "mushrooms", data.clone(), 0.1).unwrap();
    let rust = RustLogReg::new(data, 0.1);

    let mut w = vec![0.0f32; 112];
    for (j, v) in w.iter_mut().enumerate() {
        *v = ((j as f32) * 0.37).sin() * 0.5;
    }
    let mut g_h = vec![0.0f32; 112];
    let mut g_r = vec![0.0f32; 112];
    for client in 0..4 {
        let l_h = hlo.loss_grad(client, &w, &mut g_h).unwrap();
        let l_r = rust.loss_grad(client, &w, &mut g_r).unwrap();
        assert!((l_h - l_r).abs() < 1e-4, "client {client}: loss {l_h} vs {l_r}");
        let max_diff = g_h
            .iter()
            .zip(&g_r)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-4, "client {client}: grad max diff {max_diff}");
    }
}

#[test]
fn logreg_batched_artifact_matches_per_client() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest().logreg_batch_n;
    let mut rng = fedeff::rng(101);
    let data = logreg_dataset(112, 256, n, Heterogeneity::Iid, 0.3, &mut rng);
    let hlo = HloLogReg::new(rt, "mushrooms", data, 0.1).unwrap();

    let w = vec![0.05f32; 112];
    let ws: Vec<f32> = (0..n).flat_map(|_| w.clone()).collect();
    let (losses, grads) = hlo.batch_loss_grad(&ws, n).unwrap();
    assert_eq!(losses.len(), n);
    assert_eq!(grads.len(), n * 112);

    let mut g = vec![0.0f32; 112];
    for c in 0..n {
        let l = hlo.loss_grad(c, &w, &mut g).unwrap();
        assert!((losses[c] - l).abs() < 1e-4, "client {c} loss {l} vs batched {}", losses[c]);
        let gd = &grads[c * 112..(c + 1) * 112];
        let max_diff =
            gd.iter().zip(&g).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_diff < 1e-4, "client {c} grad max diff {max_diff}");
    }
}

#[test]
fn logreg_stochastic_grad_estimates_full() {
    let Some(rt) = runtime() else { return };
    let mut rng = fedeff::rng(102);
    let data = logreg_dataset(112, 256, 2, Heterogeneity::Iid, 0.3, &mut rng);
    let hlo = HloLogReg::new(rt, "mushrooms", data, 0.1).unwrap();
    let w = vec![0.1f32; 112];
    let mut full = vec![0.0f32; 112];
    hlo.loss_grad(0, &w, &mut full).unwrap();
    let mut mean = vec![0.0f32; 112];
    let mut g = vec![0.0f32; 112];
    let reps = 200;
    for _ in 0..reps {
        hlo.loss_grad_stoch(0, &w, &mut g, &mut rng).unwrap();
        fedeff::vecmath::acc_mean(&g, reps as f32, &mut mean);
    }
    let rel = fedeff::vecmath::dist_sq(&mean, &full).sqrt() / fedeff::vecmath::norm(&full);
    assert!(rel < 0.25, "stochastic grad bias too large: rel {rel}");
}

#[test]
fn mlp_grad_matches_finite_difference() {
    let Some(rt) = runtime() else { return };
    let mut rng = fedeff::rng(103);
    let data = fedeff::data::synth::fed_class_dataset(
        784,
        10,
        2,
        64,
        128,
        fedeff::data::partition::Split::Iid,
        0.5,
        &mut rng,
    );
    let hlo = HloMlp::new(rt.clone(), "emnistl", data, 1e-4).unwrap();
    let layout = rt.manifest().layout("mlp_emnistl").unwrap().clone();
    let theta = fedeff::manifest::init_flat(&layout, &mut rng);
    let d = theta.len();
    let mut g = vec![0.0f32; d];
    let l0 = hlo.loss_grad(0, &theta, &mut g).unwrap();
    assert!(l0.is_finite() && l0 > 0.0);
    // central differences on a few random coordinates
    let eps = 2e-2f32;
    let mut tmp = vec![0.0f32; d];
    for t in 0..4 {
        let j = (t * 7919 + 13) % d;
        let mut tp = theta.clone();
        let mut tm = theta.clone();
        tp[j] += eps;
        tm[j] -= eps;
        let lp = hlo.loss_grad(0, &tp, &mut tmp).unwrap();
        let lm = hlo.loss_grad(0, &tm, &mut tmp).unwrap();
        let fd = (lp - lm) / (2.0 * eps);
        assert!(
            (g[j] - fd).abs() < 0.05 * g[j].abs().max(0.05),
            "coord {j}: grad {} vs fd {fd}",
            g[j]
        );
    }
}

#[test]
fn mlp_eval_accuracy_in_unit_range() {
    let Some(rt) = runtime() else { return };
    let mut rng = fedeff::rng(104);
    let data = fedeff::data::synth::fed_class_dataset(
        784,
        10,
        2,
        64,
        256,
        fedeff::data::partition::Split::Iid,
        0.5,
        &mut rng,
    );
    let hlo = HloMlp::new(rt.clone(), "emnistl", data, 1e-4).unwrap();
    let layout = rt.manifest().layout("mlp_emnistl").unwrap().clone();
    let theta = fedeff::manifest::init_flat(&layout, &mut rng);
    let acc = hlo.test_accuracy(&theta).unwrap();
    assert!((0.0..=1.0).contains(&acc), "acc {acc}");
}

#[test]
fn lm_grad_loss_and_eval_consistent() {
    let Some(rt) = runtime() else { return };
    let prof = rt.manifest().lm_configs["lm_tiny"].clone();
    let mut rng = fedeff::rng(105);
    let data = fedeff::data::corpus::fed_token_dataset(2, 8, 16, prof.seq_len, &mut rng);
    let hlo = HloLm::new(rt.clone(), "lm_tiny", data).unwrap();
    let layout = rt.manifest().layout("lm_tiny").unwrap().clone();
    let theta = fedeff::manifest::init_flat(&layout, &mut rng);

    let mut g = vec![0.0f32; theta.len()];
    let loss = hlo.loss_grad(0, &theta, &mut g).unwrap();
    // near-uniform init -> loss near ln(96)
    assert!((loss - (96f32).ln()).abs() < 1.0, "loss {loss}");
    assert!(g.iter().all(|v| v.is_finite()));
    assert!(fedeff::vecmath::norm(&g) > 0.0);

    let ppl = hlo.eval_perplexity(&theta).unwrap();
    assert!(ppl > 1.0 && ppl < 300.0, "ppl {ppl}");

    // a few conservative GD steps on one client must reduce its loss
    let mut th = theta.clone();
    let mut l_last = loss;
    for _ in 0..12 {
        l_last = hlo.loss_grad(0, &th, &mut g).unwrap();
        let gn = fedeff::vecmath::norm(&g).max(1e-6);
        fedeff::vecmath::axpy(-(0.1 / gn).min(0.5), &g, &mut th);
    }
    assert!(l_last < loss, "{l_last} !< {loss}");
}

#[test]
fn lm_calibration_matches_layout_and_is_nonnegative() {
    let Some(rt) = runtime() else { return };
    let prof = rt.manifest().lm_configs["lm_tiny"].clone();
    let mut rng = fedeff::rng(106);
    let data = fedeff::data::corpus::fed_token_dataset(1, 4, 16, prof.seq_len, &mut rng);
    let hlo = HloLm::new(rt.clone(), "lm_tiny", data).unwrap();
    let layout = rt.manifest().layout("lm_tiny").unwrap().clone();
    let calib_layout = rt.manifest().calib_layouts["lm_tiny"].clone();
    let theta = fedeff::manifest::init_flat(&layout, &mut rng);

    let calib = hlo.calibrate(&theta, 2).unwrap();
    assert_eq!(calib.len(), calib_layout.total);
    assert!(calib.iter().all(|&v| v >= 0.0 && v.is_finite()));
    // every prunable linear layer has matching calib slice dims
    for e in layout.iter().filter(|e| e.is_prunable()) {
        let (o, i) = e.matrix_dims().unwrap();
        let (a_in, a_out) =
            fedeff::pruning::calib_slices(&calib_layout, &calib, &e.name).unwrap();
        assert_eq!(a_in.len(), i, "{}", e.name);
        assert_eq!(a_out.len(), o, "{}", e.name);
    }
}

#[test]
fn wanda_kernel_artifact_matches_rust_score() {
    let Some(rt) = runtime() else { return };
    // lm_small's (128, 128) linear shape has a compiled Pallas kernel
    let exe = match rt.load("wanda_score_128x128") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP wanda kernel: {e}");
            return;
        }
    };
    let (o, i) = (128usize, 128usize);
    let mut rng = fedeff::rng(107);
    let w: Vec<f32> = (0..o * i).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let a_in: Vec<f32> = (0..i).map(|_| rng.f32_range(0.01, 2.0)).collect();
    let a_out: Vec<f32> = (0..o).map(|_| rng.f32_range(0.01, 2.0)).collect();
    let alpha = [0.7f32];
    let out = exe.run(&[&w, &a_in, &a_out, &alpha]).unwrap();
    let rust = fedeff::pruning::score(
        fedeff::pruning::Method::SymWanda { alpha: 0.7 },
        &w,
        o,
        i,
        &a_in,
        &a_out,
    );
    let max_diff =
        out[0].iter().zip(&rust).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "pallas-vs-rust wanda score diff {max_diff}");
}

#[test]
fn ria_kernel_artifact_matches_rust_score() {
    let Some(rt) = runtime() else { return };
    let exe = match rt.load("ria_score_384x128") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP ria kernel: {e}");
            return;
        }
    };
    let (o, i) = (384usize, 128usize);
    let mut rng = fedeff::rng(108);
    let w: Vec<f32> = (0..o * i).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let a_in: Vec<f32> = (0..i).map(|_| rng.f32_range(0.01, 2.0)).collect();
    let a_out: Vec<f32> = (0..o).map(|_| rng.f32_range(0.01, 2.0)).collect();
    let out = exe.run(&[&w, &a_in, &a_out, &[0.5f32], &[0.5f32]]).unwrap();
    let rust = fedeff::pruning::score(
        fedeff::pruning::Method::Ria { alpha: 0.5, p: 0.5 },
        &w,
        o,
        i,
        &a_in,
        &a_out,
    );
    let max_rel = out[0]
        .iter()
        .zip(&rust)
        .map(|(a, b)| (a - b).abs() / b.abs().max(1e-6))
        .fold(0.0f32, f32::max);
    assert!(max_rel < 1e-3, "pallas-vs-rust ria score rel diff {max_rel}");
}

#[test]
fn staged_buffers_match_fresh_literals() {
    let Some(rt) = runtime() else { return };
    let mut rng = fedeff::rng(109);
    let data = logreg_dataset(112, 256, 1, Heterogeneity::Iid, 0.3, &mut rng);
    let exe = rt.load("logreg_grad_mushrooms").unwrap();
    let shard = &data.clients[0];
    let w = vec![0.02f32; 112];
    let mu = [0.1f32];
    // path A: all host literals
    let a = exe.run(&[&shard.x, &shard.y, &w, &mu]).unwrap();
    // path B: staged device buffers for X, y
    let sx = rt.stage(&shard.x, &[256, 112]).unwrap();
    let sy = rt.stage(&shard.y, &[256]).unwrap();
    let b = exe
        .run_mixed(&[
            fedeff::runtime::Input::Staged(&sx),
            fedeff::runtime::Input::Staged(&sy),
            fedeff::runtime::Input::Host(&w),
            fedeff::runtime::Input::Host(&mu),
        ])
        .unwrap();
    assert_eq!(a[0], b[0]);
    assert_eq!(a[1], b[1]);
}
