//! Masked federated training: the sparsity subsystem's safety net.
//!
//! Pins the mask invariants of the refactor: structured N:M masks keep
//! exactly n of every m inputs per row; masked runs over the O(nnz)
//! sparse message path are bit-for-bit identical to the dense-masked
//! reference path (`with_sparse_links(false)`), flat and over executed
//! trees, global and personalized; a 0%-sparsity mask reproduces the
//! unmasked driver exactly (identical losses and uplink bits — the
//! downlink differs by exactly the documented mask-transmission
//! charge); and the acceptance composition — a TOML-only FedAvg run
//! with a 50% SymWanda mask and a Top-K uplink — completes over both
//! flat and 3-level tree topologies while booking strictly fewer
//! uplink bits than the dense run of the same experiment, mask charge
//! included.

use fedeff::algorithms::fedavg::FedAvg;
use fedeff::algorithms::gd::Gd;
use fedeff::algorithms::scaffold::Scaffold;
use fedeff::algorithms::{build_algorithm, RunOptions};
use fedeff::compress::randk::RandK;
use fedeff::compress::sparse_bits;
use fedeff::compress::topk::TopK;
use fedeff::coordinator::driver::Driver;
use fedeff::metrics::RunRecord;
use fedeff::oracle::quadratic::QuadraticOracle;
use fedeff::pruning::{Method, Scope};
use fedeff::sparsity::{MaskSpec, MaskState};

fn quadratic(seed: u64, n: usize, d: usize) -> QuadraticOracle {
    let mut rng = fedeff::rng(seed);
    QuadraticOracle::random(n, d, 0.5, 2.0, 1.0, &mut rng)
}

fn symwanda_mask(sparsity: f32) -> MaskSpec {
    MaskSpec { method: Method::SymWanda { alpha: 0.5 }, sparsity, ..MaskSpec::default() }
}

fn assert_records_bitwise_eq(a: &RunRecord, b: &RunRecord, what: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: record lengths differ");
    for (i, (ra, rb)) in a.rounds.iter().zip(&b.rounds).enumerate() {
        assert!(ra.loss == rb.loss, "{what}: entry {i} loss {} vs {}", ra.loss, rb.loss);
        assert_eq!(ra.bits_up, rb.bits_up, "{what}: entry {i} bits_up");
        assert_eq!(ra.bits_down, rb.bits_down, "{what}: entry {i} bits_down");
    }
}

/// Structured N:M selection really is structured: with the flat model
/// scored as 4 rows of 8 inputs, a 2:4 mask keeps exactly 2 of every 4
/// consecutive inputs in every row.
#[test]
fn structured_nm_mask_keeps_exactly_n_of_every_m() {
    let q = quadratic(90, 3, 32);
    let spec = MaskSpec {
        method: Method::SymWanda { alpha: 0.5 },
        scope: Scope::StructuredNm { n: 2, m: 4 },
        rows: 4,
        ..MaskSpec::default()
    };
    let ms = MaskState::build(&spec, &q, &vec![1.0f32; 32], 7).unwrap();
    let mask = ms.set.global().unwrap();
    assert_eq!(mask.nnz(), 16);
    let i = 8; // inputs per row
    for r in 0..4 {
        for c4 in 0..2 {
            let kept = (0..4).filter(|&j| mask.is_kept(r * i + c4 * 4 + j)).count();
            assert_eq!(kept, 2, "row {r} block {c4} keeps {kept} != 2");
        }
    }
}

/// Masked-sparse vs masked-dense: the O(nnz) SparseVec path must match
/// the dense-masked reference bit for bit (GD + Rand-K exercises the
/// link RNG; FedAvg + Top-K exercises the FedCOM delta path).
#[test]
fn masked_sparse_matches_masked_dense_gd_randk() {
    let q = quadratic(91, 6, 64);
    let x0 = vec![1.0f32; 64];
    let opts = RunOptions { rounds: 60, eval_every: 15, seed: 3, ..Default::default() };
    let mk = |sparse: bool| {
        Driver::new()
            .with_up(Box::new(RandK::scaled(8)))
            .with_mask(symwanda_mask(0.5))
            .with_sparse_links(sparse)
    };
    let mut a = Gd::plain(6, 64, 0.1);
    let rec_dense = mk(false).run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = Gd::plain(6, 64, 0.1);
    let rec_sparse = mk(true).run(&mut b, &q, &x0, &opts).unwrap();
    assert_records_bitwise_eq(&rec_dense, &rec_sparse, "masked GD+RandK");
    assert_eq!(rec_sparse.mask_nnz, Some(32));
}

#[test]
fn masked_sparse_matches_masked_dense_fedavg_topk() {
    let q = quadratic(92, 8, 48);
    let x0 = vec![2.0f32; 48];
    let opts = RunOptions { rounds: 80, eval_every: 20, seed: 5, ..Default::default() };
    let mk = |sparse: bool| {
        Driver::new()
            .with_up(Box::new(TopK::new(6)))
            .with_down(Box::new(TopK::new(6)))
            .with_mask(symwanda_mask(0.5))
            .with_sparse_links(sparse)
    };
    let mut a = FedAvg::new(3, 0.1);
    let rec_dense = mk(false).run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = FedAvg::new(3, 0.1);
    let rec_sparse = mk(true).run(&mut b, &q, &x0, &opts).unwrap();
    assert_records_bitwise_eq(&rec_dense, &rec_sparse, "masked FedAvg+TopK");
    // compressed masked uplink: Top-K bits at support-relative width
    let per_round = sparse_bits(6, 24);
    assert_eq!(rec_sparse.rounds.last().unwrap().bits_up, per_round * 80);
}

/// FedP3-style personalized masks (per-client supports, dense
/// broadcast) keep the sparse/dense equivalence too — including the
/// two-channel Scaffold uplink.
#[test]
fn masked_sparse_matches_masked_dense_personalized() {
    let q = quadratic(93, 6, 40);
    let x0 = vec![1.5f32; 40];
    let opts = RunOptions { rounds: 60, eval_every: 20, seed: 9, ..Default::default() };
    let spec = MaskSpec { personalized: true, ..symwanda_mask(0.5) };
    let mk = |sparse: bool| {
        Driver::new()
            .with_up(Box::new(TopK::new(5)))
            .with_mask(spec.clone())
            .with_sparse_links(sparse)
    };
    let mut a = FedAvg::new(2, 0.1);
    let rec_dense = mk(false).run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = FedAvg::new(2, 0.1);
    let rec_sparse = mk(true).run(&mut b, &q, &x0, &opts).unwrap();
    assert_records_bitwise_eq(&rec_dense, &rec_sparse, "personalized FedAvg+TopK");

    let mut c = Scaffold::new(3, 0.05);
    let rec_sc_dense = mk(false).run(&mut c, &q, &x0, &opts).unwrap();
    let mut e = Scaffold::new(3, 0.05);
    let rec_sc_sparse = mk(true).run(&mut e, &q, &x0, &opts).unwrap();
    assert_records_bitwise_eq(&rec_sc_dense, &rec_sc_sparse, "personalized Scaffold+TopK");
}

/// A 0%-sparsity mask is the identity on the message path: identical
/// losses and identical uplink bits to the unmasked driver; the
/// downlink differs by exactly the documented one-time mask charge
/// (`d` bits, booked before round 0).
#[test]
fn zero_sparsity_mask_reproduces_unmasked_driver() {
    let d = 64usize;
    let q = quadratic(94, 6, d);
    let x0 = vec![1.0f32; d];
    let opts = RunOptions { rounds: 60, eval_every: 15, seed: 3, ..Default::default() };

    // dense GD (no compressor): masked dense payloads at nnz = d
    let mut a = Gd::plain(6, d, 0.1);
    let rec_plain = Driver::new().run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = Gd::plain(6, d, 0.1);
    let rec_masked =
        Driver::new().with_mask(symwanda_mask(0.0)).run(&mut b, &q, &x0, &opts).unwrap();
    assert_eq!(rec_masked.mask_nnz, Some(d as u64));
    assert_eq!(rec_plain.rounds.len(), rec_masked.rounds.len());
    for (rp, rm) in rec_plain.rounds.iter().zip(&rec_masked.rounds) {
        assert!(rp.loss == rm.loss, "0%-mask GD loss {} vs {}", rp.loss, rm.loss);
        assert_eq!(rp.bits_up, rm.bits_up, "0%-mask GD bits_up");
        assert_eq!(rp.bits_down + d as u64, rm.bits_down, "0%-mask GD mask charge");
    }

    // FedAvg + Top-K: the compressed FedCOM delta path, full support
    let mut a = FedAvg::new(3, 0.1);
    let drv = Driver::new().with_up(Box::new(TopK::new(8)));
    let rec_plain = drv.run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = FedAvg::new(3, 0.1);
    let drv_m = Driver::new().with_up(Box::new(TopK::new(8))).with_mask(symwanda_mask(0.0));
    let rec_masked = drv_m.run(&mut b, &q, &x0, &opts).unwrap();
    for (rp, rm) in rec_plain.rounds.iter().zip(&rec_masked.rounds) {
        assert!(rp.loss == rm.loss, "0%-mask FedAvg loss {} vs {}", rp.loss, rm.loss);
        assert_eq!(rp.bits_up, rm.bits_up, "0%-mask FedAvg bits_up");
        assert_eq!(rp.bits_down + d as u64, rm.bits_down, "0%-mask FedAvg mask charge");
    }
}

/// Mask refresh re-prunes from the current server model and re-charges
/// the mask transmission: two extra `d`-bit downlink charges over 30
/// rounds at refresh = 10, with the run still progressing.
#[test]
fn mask_refresh_recharges_and_still_trains() {
    let d = 32usize;
    let q = quadratic(95, 5, d);
    let x0 = vec![1.0f32; d];
    let opts = RunOptions { rounds: 30, eval_every: 30, seed: 2, ..Default::default() };
    let fixed = MaskSpec { method: Method::Magnitude, sparsity: 0.5, ..MaskSpec::default() };
    let refreshing = MaskSpec { refresh: Some(10), ..fixed.clone() };
    let mut a = FedAvg::new(2, 0.1);
    let rec_fixed = Driver::new().with_mask(fixed).run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = FedAvg::new(2, 0.1);
    let rec_refresh = Driver::new().with_mask(refreshing).run(&mut b, &q, &x0, &opts).unwrap();
    // refreshes at t = 10 and t = 20 book one extra mask each; the
    // masked dense payloads are support-sized either way (same nnz)
    let (lf, lr) = (rec_fixed.rounds.last().unwrap(), rec_refresh.rounds.last().unwrap());
    assert_eq!(lf.bits_down + 2 * d as u64, lr.bits_down);
    let first = rec_refresh.rounds.first().unwrap().loss;
    assert!(lr.loss.is_finite() && lr.loss < first, "{first} -> {}", lr.loss);
}

/// Acceptance pin: a TOML-only FedAvg run with a 50% SymWanda mask and
/// a Top-K uplink completes over both flat and 3-level tree topologies
/// and books strictly fewer uplink bits than the dense run of the same
/// experiment — mask transmission charge included — while the masked
/// tree aggregates bit-for-bit identically over the sparse and the
/// dense-masked reference paths.
#[test]
fn toml_masked_fedavg_topk_books_fewer_uplink_bits_flat_and_tree() {
    let (n, d, rounds) = (12usize, 1024usize, 40usize);
    let q = quadratic(96, n, d);
    let x0 = vec![1.0f32; d];
    let opts = RunOptions { rounds, eval_every: rounds, seed: 2, ..Default::default() };

    let base = r#"
[experiment]
name = "masked-e2e"
rounds = 40
seed = 2

[dataset]
clients = 12

[algorithm]
kind = "fedavg"
local_steps = 2
lr = 0.1

[compressor]
up = "top-k"
k = 32

[sparsity]
method = "symwanda"
alpha = 0.5
scope = "per-matrix"
sparsity = 0.5
"#;
    let tree_section =
        "\n[topology]\nlevels = 3\nhubs = 4\n\n[links.up.l1]\nkind = \"top-k\"\nk = 64\n";

    let run = |toml: &str, masked: bool| -> RunRecord {
        let toml = if masked {
            toml.to_string()
        } else {
            // the dense reference: same spec minus the [sparsity] section
            let i = toml.find("[sparsity]").expect("spec has a sparsity section");
            let j = toml[i..].find("\n[").map(|j| i + j).unwrap_or(toml.len());
            format!("{}{}", &toml[..i], &toml[j..])
        };
        let spec = fedeff::config::Spec::parse(&toml).unwrap();
        let mut alg = build_algorithm(&spec.algorithm, &q).unwrap();
        let driver = fedeff::config::build_driver(&spec, n).unwrap();
        driver.run(alg.as_mut(), &q, &x0, &opts).unwrap()
    };

    // ---- flat ----
    let rec_masked = run(base, true);
    let rec_dense = run(base, false);
    assert_eq!(rec_masked.mask_nnz, Some(512));
    let (lm, ld) = (rec_masked.rounds.last().unwrap(), rec_dense.rounds.last().unwrap());
    assert!(lm.loss.is_finite() && ld.loss.is_finite());
    // masked Top-K books support-relative index widths every round...
    assert_eq!(lm.bits_up, sparse_bits(32, 512) * rounds as u64);
    assert_eq!(ld.bits_up, sparse_bits(32, 1024) * rounds as u64);
    // ...and stays strictly cheaper than dense even after paying the
    // mask's own d-bit transmission
    assert!(
        lm.bits_up + d as u64 < ld.bits_up,
        "masked uplink (+mask charge) {} must undercut dense {}",
        lm.bits_up + d as u64,
        ld.bits_up
    );

    // ---- 3-level tree (clients -> 4 hubs -> server) ----
    let tree_toml = format!("{base}{tree_section}");
    let rec_masked_t = run(&tree_toml, true);
    let rec_dense_t = run(&tree_toml, false);
    let (lmt, ldt) = (rec_masked_t.rounds.last().unwrap(), rec_dense_t.rounds.last().unwrap());
    assert!(lmt.loss.is_finite() && ldt.loss.is_finite());
    assert_eq!(rec_masked_t.edge_bits_up.len(), 2);
    // leaf and hub edges both carry support-sized traffic
    assert_eq!(rec_masked_t.edge_bits_up[0], 12 * sparse_bits(32, 512) * rounds as u64);
    assert_eq!(rec_masked_t.edge_bits_up[1], 4 * sparse_bits(64, 512) * rounds as u64);
    assert!(
        lmt.bits_up + d as u64 < ldt.bits_up,
        "masked tree uplink (+mask charge) {} must undercut dense {}",
        lmt.bits_up + d as u64,
        ldt.bits_up
    );

    // the masked tree's O(nnz) sparse path == dense-masked reference
    let spec = fedeff::config::Spec::parse(&tree_toml).unwrap();
    let mut alg = build_algorithm(&spec.algorithm, &q).unwrap();
    let mut driver = fedeff::config::build_driver(&spec, n).unwrap();
    driver.sparse_links = false;
    let rec_ref = driver.run(alg.as_mut(), &q, &x0, &opts).unwrap();
    assert_records_bitwise_eq(&rec_masked_t, &rec_ref, "masked tree sparse vs dense");
    assert_eq!(rec_masked_t.edge_bits_up, rec_ref.edge_bits_up);
}

/// Masked runs still optimize: a 50% mask costs accuracy but the loss
/// must strictly decrease for every masked algorithm that routes the
/// masked link path — including Scafflix's anchored uplink.
#[test]
fn masked_runs_converge_across_algorithms() {
    let q = quadratic(97, 6, 32);
    let x0 = vec![2.0f32; 32];
    for kind in ["gd", "fedavg", "fedprox", "scaffold", "scafflix"] {
        let spec = fedeff::config::AlgorithmSpec {
            kind: kind.to_string(),
            k: Some(2),
            ..Default::default()
        };
        let mut alg = build_algorithm(&spec, &q).unwrap();
        let opts = RunOptions { rounds: 150, eval_every: 150, seed: 4, ..Default::default() };
        let drv = Driver::new().with_mask(symwanda_mask(0.5));
        let rec = drv.run(alg.as_mut(), &q, &x0, &opts).unwrap();
        let first = rec.rounds.first().unwrap().loss;
        let last = rec.rounds.last().unwrap().loss;
        assert!(last.is_finite() && last < first, "{kind}: masked run {first} -> {last}");
    }
}
