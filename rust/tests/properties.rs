//! Property-based tests (hand-rolled generator loops over the in-tree
//! deterministic RNG — no external proptest offline) for the coordinator
//! substrates: compressor class bounds, mask invariants, sampling
//! invariants, prox optimality, ledger monotonicity.

use fedeff::compress::comp::CompKK;
use fedeff::compress::mix::MixKK;
use fedeff::compress::quantize::Qsgd;
use fedeff::compress::randk::RandK;
use fedeff::compress::topk::TopK;
use fedeff::compress::{Compressor, Identity};
use fedeff::Rng;

fn rand_vec(rng: &mut Rng, d: usize, scale: f32) -> Vec<f32> {
    (0..d).map(|_| rng.f32_range(-scale, scale)).collect()
}

/// Property: for every compressor C in B(alpha) (after lambda* scaling),
/// E||lambda C(x) - x||^2 <= (1 - alpha + tol) ||x||^2 on random inputs.
#[test]
fn prop_scaled_compressors_are_contractive() {
    let mut rng = fedeff::rng(300);
    for trial in 0..40 {
        let d = 8 + rng.below(56);
        let k = 1 + rng.below(d.min(8));
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(TopK::new(k)),
            Box::new(RandK::unbiased(k)),
            Box::new(RandK::scaled(k)),
            Box::new(MixKK::new(k, (2 * k).min(d))),
            Box::new(Qsgd::new(4)),
            Box::new(Identity),
        ];
        let x = rand_vec(&mut rng, d, 2.0);
        let nx2 = fedeff::vecmath::norm_sq(&x).max(1e-9);
        for c in &comps {
            let p = c.params(d);
            let lambda = p.lambda_star();
            let r = p.r(lambda);
            assert!(r <= 1.0 + 1e-5, "{} r={r}", c.name());
            // empirical contraction with the scaled compressor
            let reps = 300;
            let mut acc = 0.0f64;
            let mut out = vec![0.0f32; d];
            for _ in 0..reps {
                c.compress(&x, &mut out, &mut rng);
                fedeff::vecmath::scale(lambda, &mut out);
                acc += fedeff::vecmath::dist_sq(&out, &x) as f64 / reps as f64;
            }
            let ratio = acc / nx2 as f64;
            assert!(
                ratio <= r as f64 * 1.25 + 0.05,
                "trial {trial} {}: empirical {ratio} > bound {r}",
                c.name()
            );
        }
    }
}

/// Property: compressed output of sparsifiers has at most k nonzeros, and
/// bit accounting is positive and bounded by the dense message.
#[test]
fn prop_sparsifier_support_and_bits() {
    let mut rng = fedeff::rng(301);
    for _ in 0..60 {
        let d = 4 + rng.below(124);
        let k = 1 + rng.below(d);
        let x = rand_vec(&mut rng, d, 1.0);
        let mut out = vec![0.0f32; d];
        for c in [&TopK::new(k) as &dyn Compressor, &RandK::unbiased(k)] {
            let bits = c.compress(&x, &mut out, &mut rng);
            let nnz = out.iter().filter(|&&v| v != 0.0).count();
            assert!(nnz <= k, "{}: {nnz} > {k}", c.name());
            assert!(bits > 0);
        }
    }
}

/// Property: select_mask keeps exactly the requested fraction per row and
/// apply_mask never increases density.
#[test]
fn prop_mask_sparsity_exact() {
    let mut rng = fedeff::rng(302);
    for _ in 0..50 {
        let o = 1 + rng.below(12);
        let i = 2 + rng.below(40);
        let sparsity = rng.f32_range(0.1, 0.9);
        let scores: Vec<f32> = (0..o * i).map(|_| rng.f32_unit()).collect();
        let mask =
            fedeff::pruning::select_mask(&scores, o, i, sparsity, fedeff::pruning::Scope::PerRow);
        let keep = (((1.0 - sparsity) * i as f32).round() as usize).min(i);
        for r in 0..o {
            let kept = mask[r * i..(r + 1) * i].iter().filter(|&&k| k).count();
            assert_eq!(kept, keep, "row {r}: kept {kept} expected {keep}");
        }
        let mut w: Vec<f32> = (0..o * i).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        fedeff::pruning::apply_mask(&mut w, &mask);
        let nnz = w.iter().filter(|&&v| v != 0.0).count();
        assert!(nnz <= keep * o);
    }
}

/// Property: every sampler yields cohorts within [0, n), nonempty, with
/// inclusion frequencies matching p_i within statistical tolerance.
#[test]
fn prop_sampler_inclusion_matches_p() {
    use fedeff::sampling::*;
    let mut rng = fedeff::rng(303);
    for trial in 0..8 {
        let n = 6 + rng.below(18);
        let b = 2 + rng.below(4.min(n - 1));
        let samplers: Vec<Box<dyn CohortSampler>> = vec![
            Box::new(FullSampling { n }),
            Box::new(NiceSampling { n, tau: 1 + rng.below(n) }),
            Box::new(BlockSampling::new(contiguous_blocks(n, b), None)),
            Box::new(StratifiedSampling::new(contiguous_blocks(n, b))),
        ];
        for s in &samplers {
            let trials = 3000;
            let mut counts = vec![0usize; n];
            for _ in 0..trials {
                let c = s.sample(&mut rng);
                assert!(!c.is_empty(), "{}", s.name());
                for i in c {
                    assert!(i < n);
                    counts[i] += 1;
                }
            }
            for i in 0..n {
                let freq = counts[i] as f64 / trials as f64;
                let p = s.p(i);
                assert!(
                    (freq - p).abs() < 0.06 + 0.15 * p,
                    "trial {trial} {} client {i}: freq {freq} vs p {p}",
                    s.name()
                );
            }
        }
    }
}

/// Property: prox solvers converge to the closed-form prox on random
/// quadratic cohorts; error decreases with more local rounds.
#[test]
fn prop_prox_solvers_approach_exact() {
    use fedeff::oracle::quadratic::QuadraticOracle;
    use fedeff::oracle::Oracle;
    use fedeff::prox::*;
    let mut rng = fedeff::rng(304);
    for trial in 0..10 {
        let n = 4 + rng.below(6);
        let d = 3 + rng.below(10);
        let q = QuadraticOracle::random(n, d, 0.4, 3.0, 2.0, &mut rng);
        let gamma = rng.f32_range(0.2, 5.0);
        let cohort: Vec<(usize, f32)> = (0..n).filter(|i| i % 2 == 0).map(|i| (i, 1.0)).collect();
        let x = rand_vec(&mut rng, d, 1.5);
        let exact = q.prox_cohort(&cohort, &x, gamma);
        let lip: f32 = cohort.iter().map(|&(i, w)| w * q.smoothness(i)).sum();

        for solver in [&LbfgsSolver::default() as &dyn ProxSolver, &CgSolver] {
            let mut tmp = vec![0.0f32; d];
            let mut obj = |y: &[f32], g: &mut [f32]| -> anyhow::Result<f32> {
                g.fill(0.0);
                let mut loss = 0.0;
                for &(i, w) in &cohort {
                    loss += w * q.loss_grad(i, y, &mut tmp)?;
                    fedeff::vecmath::axpy(w, &tmp, g);
                }
                Ok(loss)
            };
            let y = solver.solve(&mut obj, &x, gamma, 60, &x, lip).unwrap();
            let err = fedeff::vecmath::dist_sq(&y, &exact).sqrt();
            let scale = fedeff::vecmath::norm(&exact).max(1.0);
            assert!(err < 1e-2 * scale, "trial {trial} {}: err {err}", solver.name());
        }
    }
}

/// Property: DSnoT preserves per-row sparsity for random inits and never
/// panics across shapes.
#[test]
fn prop_dsnot_preserves_sparsity() {
    use fedeff::pruning::dsnot::*;
    let mut rng = fedeff::rng(305);
    for _ in 0..30 {
        let o = 1 + rng.below(10);
        let i = 4 + rng.below(30);
        let mut w: Vec<f32> = (0..o * i).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let a_in: Vec<f32> = (0..i).map(|_| rng.f32_range(0.05, 3.0)).collect();
        let a_out: Vec<f32> = (0..o).map(|_| rng.f32_range(0.05, 3.0)).collect();
        let scores = fedeff::pruning::score(
            fedeff::pruning::Method::Wanda,
            &w,
            o,
            i,
            &a_in,
            &a_out,
        );
        let sparsity = rng.f32_range(0.2, 0.8);
        let mut mask =
            fedeff::pruning::select_mask(&scores, o, i, sparsity, fedeff::pruning::Scope::PerRow);
        let before: Vec<usize> = (0..o)
            .map(|r| mask[r * i..(r + 1) * i].iter().filter(|&&k| k).count())
            .collect();
        prune_and_grow_layer(
            &mut w,
            &mut mask,
            o,
            i,
            &a_in,
            &a_out,
            &DsnotConfig { iters: 2, reg: 0.05, relative_grow: true, alpha: 0.5 },
        );
        let after: Vec<usize> = (0..o)
            .map(|r| mask[r * i..(r + 1) * i].iter().filter(|&&k| k).count())
            .collect();
        assert_eq!(before, after, "per-row sparsity must be preserved");
        // weights outside the mask are zero
        for (j, &keep) in mask.iter().enumerate() {
            if !keep {
                assert_eq!(w[j], 0.0);
            }
        }
    }
}

/// Property: EF-BV state update keeps h_i bounded and converges on random
/// well-conditioned quadratics for random sparsifiers.
#[test]
fn prop_efbv_random_instances_converge() {
    use fedeff::algorithms::efbv::EfBv;
    use fedeff::algorithms::RunOptions;
    use fedeff::coordinator::driver::Driver;
    use fedeff::oracle::quadratic::QuadraticOracle;
    use fedeff::oracle::Oracle;
    let mut rng = fedeff::rng(306);
    for trial in 0..5 {
        let n = 4 + rng.below(6);
        let d = 6 + rng.below(10);
        let k = 1 + rng.below(3);
        let q = QuadraticOracle::random(n, d, 0.5, 2.0, 1.0, &mut rng);
        let xs = q.minimizer();
        let fs = q.full_loss(&xs).unwrap();
        let mut alg = EfBv::ef21(Box::new(TopK::new(k)));
        let opts = RunOptions {
            rounds: 1500,
            eval_every: 1500,
            f_star: Some(fs),
            seed: trial as u64,
            ..Default::default()
        };
        let rec = Driver::new().run(&mut alg, &q, &vec![1.0; d], &opts).unwrap();
        let gap = rec.last().unwrap().gap.unwrap();
        assert!(gap < 1e-2, "trial {trial} (n={n},d={d},k={k}): gap {gap}");
    }
}

/// Property: the communication ledger is monotone in rounds for every
/// algorithm's RunRecord.
#[test]
fn prop_ledger_monotone() {
    use fedeff::algorithms::fedavg::FedAvg;
    use fedeff::algorithms::RunOptions;
    use fedeff::coordinator::driver::Driver;
    use fedeff::oracle::quadratic::QuadraticOracle;
    use fedeff::sampling::NiceSampling;
    let mut rng = fedeff::rng(307);
    let q = QuadraticOracle::random(6, 5, 0.5, 2.0, 1.0, &mut rng);
    let mut alg = FedAvg::new(3, 0.1);
    let opts = RunOptions { rounds: 50, eval_every: 5, ..Default::default() };
    let drv = Driver::new().with_sampler(Box::new(NiceSampling { n: 6, tau: 3 }));
    let rec = drv.run(&mut alg, &q, &vec![1.0; 5], &opts).unwrap();
    for w in rec.rounds.windows(2) {
        assert!(w[1].bits_up >= w[0].bits_up);
        assert!(w[1].bits_down >= w[0].bits_down);
        assert!(w[1].comm_cost >= w[0].comm_cost);
        assert!(w[1].round > w[0].round);
    }
}
