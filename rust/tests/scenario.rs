//! Acceptance pins for the time-aware scenario engine (DESIGN.md
//! §Scenario):
//!
//! * a zero-straggler / zero-dropout sync scenario is **bit-for-bit**
//!   the plain driver in loss and ledger — the virtual clock is
//!   bookkeeping on the side, never a different execution;
//! * identical seeds replay identical timelines (losses, booked bits
//!   *and* virtual timestamps) across serial, pool and fused runs;
//! * mid-round dropout over a 3-level tree completes the round with
//!   correctly down-weighted partial hubs, and the ledger books only
//!   the bits survivors actually sent — pinned by scripting the
//!   engine's own survivor cohorts into an untimed reference driver;
//! * buffered-async aggregation reaches a target loss in **less
//!   virtual time** than the sync barrier under a heavy-tailed
//!   (Pareto) straggler profile, replays bitwise at a fixed seed, and
//!   rejects unsupported configurations loudly.

use std::cell::RefCell;
use std::collections::VecDeque;

use fedeff::algorithms::fedavg::FedAvg;
use fedeff::algorithms::scaffold::Scaffold;
use fedeff::algorithms::RunOptions;
use fedeff::coordinator::driver::{Driver, Topology};
use fedeff::coordinator::hierarchy::AggTree;
use fedeff::metrics::RunRecord;
use fedeff::oracle::quadratic::QuadraticOracle;
use fedeff::sampling::{CohortSampler, NiceSampling};
use fedeff::scenario::{event_rng, Dist, Mode, ScenarioSpec, Staleness, EV_DROP};
use fedeff::Rng;

fn quadratic(seed: u64, n: usize, d: usize) -> QuadraticOracle {
    let mut rng = fedeff::rng(seed);
    QuadraticOracle::random(n, d, 0.5, 2.0, 1.0, &mut rng)
}

/// Bit-for-bit equality in loss, booked bits and comm cost; the virtual
/// clock column is compared only when both records carry one.
fn assert_records_eq(a: &RunRecord, b: &RunRecord, vtime_too: bool, what: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: record lengths differ");
    for (i, (ra, rb)) in a.rounds.iter().zip(&b.rounds).enumerate() {
        assert!(ra.loss == rb.loss, "{what}: entry {i} loss {} vs {}", ra.loss, rb.loss);
        assert_eq!(ra.bits_up, rb.bits_up, "{what}: entry {i} bits_up");
        assert_eq!(ra.bits_down, rb.bits_down, "{what}: entry {i} bits_down");
        assert!(
            ra.comm_cost == rb.comm_cost,
            "{what}: entry {i} comm_cost {} vs {}",
            ra.comm_cost,
            rb.comm_cost
        );
        if vtime_too {
            assert_eq!(
                ra.vtime.to_bits(),
                rb.vtime.to_bits(),
                "{what}: entry {i} vtime {} vs {}",
                ra.vtime,
                rb.vtime
            );
        }
    }
    assert_eq!(a.edge_bits_up, b.edge_bits_up, "{what}: per-edge ledger");
}

/// A zero-effect scenario (fixed unit compute, no stragglers, no
/// dropout, sync barrier) is the plain driver bit-for-bit — including a
/// composed configuration (Top-K uplink + cohort sampling).
#[test]
fn sync_zero_effect_scenario_matches_untimed_driver() {
    let q = quadratic(90, 10, 24);
    let x0 = vec![1.0f32; 24];
    let opts = RunOptions { rounds: 40, eval_every: 10, seed: 3, ..Default::default() };
    let mk = || {
        Driver::new()
            .with_sampler(Box::new(NiceSampling { n: 10, tau: 5 }))
            .with_up(Box::new(fedeff::compress::topk::TopK::new(6)))
    };
    let mut a = FedAvg::new(3, 0.1);
    let rec_plain = mk().run(&mut a, &q, &x0, &opts).unwrap();
    let mut b = FedAvg::new(3, 0.1);
    let spec = ScenarioSpec::default();
    let rec_timed = mk().run_scenario(&mut b, &q, &spec, &x0, &opts).unwrap();
    assert_records_eq(&rec_plain, &rec_timed, false, "zero-effect scenario");
    // the clock still ran: virtual timestamps are positive and monotone
    let stat = rec_timed.scenario.expect("scenario stat");
    assert!(stat.vtime > 0.0);
    assert_eq!((stat.dropped, stat.unavailable), (0, 0));
    assert_eq!(stat.applies, 40);
    let vts: Vec<f64> = rec_timed.rounds.iter().map(|r| r.vtime).collect();
    assert!(vts.windows(2).all(|w| w[0] < w[1]), "vtime not monotone: {vts:?}");
    assert!(rec_plain.rounds.iter().all(|r| r.vtime == 0.0), "untimed run must report 0");
}

/// Fixed seed => identical event timeline, losses and booked bits
/// across serial, reference-pool and fused execution, under stragglers,
/// unavailability AND dropout.
#[test]
fn sync_timeline_bit_identical_across_serial_pool_fused() {
    let q = quadratic(91, 12, 32);
    let x0 = vec![1.5f32; 32];
    let opts = RunOptions { rounds: 50, eval_every: 10, seed: 7, ..Default::default() };
    let spec = ScenarioSpec {
        compute: Dist::Pareto { scale: 0.05, shape: 1.1 },
        speed: Dist::Uniform { lo: 0.5, hi: 2.0 },
        bandwidth: 1e4,
        drop: 0.15,
        unavailable: 0.1,
        mode: Mode::Sync,
    };
    let mk = || {
        Driver::new()
            .with_sampler(Box::new(NiceSampling { n: 12, tau: 6 }))
            .with_up(Box::new(fedeff::compress::topk::TopK::new(4)))
    };
    let mut a = FedAvg::new(2, 0.1);
    let rec_serial = mk().run_scenario(&mut a, &q, &spec, &x0, &opts).unwrap();
    let mut b = FedAvg::new(2, 0.1);
    let rec_fused = mk().run_scenario_parallel(&mut b, &q, &spec, &x0, &opts).unwrap();
    let mut c = FedAvg::new(2, 0.1);
    let rec_ref = mk()
        .with_fused_uplink(false)
        .run_scenario_parallel(&mut c, &q, &spec, &x0, &opts)
        .unwrap();
    assert_records_eq(&rec_serial, &rec_fused, true, "scenario serial vs fused");
    assert_records_eq(&rec_serial, &rec_ref, true, "scenario serial vs reference pool");
    let (sa, sb, sc) = (rec_serial.scenario, rec_fused.scenario, rec_ref.scenario);
    assert_eq!(sa, sb, "scenario stat serial vs fused");
    assert_eq!(sa, sc, "scenario stat serial vs reference pool");
    let stat = sa.expect("scenario stat");
    // the profile really bit: some clients dropped or sat out
    assert!(stat.dropped > 0, "expected mid-round dropouts, got {stat:?}");
    assert!(stat.unavailable > 0, "expected unavailable clients, got {stat:?}");
}

/// Replays a pre-recorded cohort per round (and inclusion probability
/// 1, matching a sampler-less timed run).
struct ScriptedSampler {
    n: usize,
    rounds: RefCell<VecDeque<Vec<usize>>>,
}

impl CohortSampler for ScriptedSampler {
    fn sample(&self, _rng: &mut Rng) -> Vec<usize> {
        self.rounds.borrow_mut().pop_front().expect("scripted sampler exhausted")
    }
    fn p(&self, _i: usize) -> f64 {
        1.0
    }
    fn n_clients(&self) -> usize {
        self.n
    }
    fn name(&self) -> String {
        "Scripted".into()
    }
}

/// Mid-round dropout under an executed 3-level tree with hub
/// re-compression: the round completes with the surviving (partial)
/// hubs, and the ledger books exactly the bits the survivors sent —
/// pinned bit-for-bit against an untimed driver fed the engine's own
/// survivor cohorts through a scripted sampler. The survivor cohorts
/// are recomputed here from the *public* [`event_rng`] streams and the
/// documented draw order (availability → compute → dropout), so this
/// test also pins that contract.
#[test]
fn tree_dropout_completes_partial_hubs_and_books_only_sent_bits() {
    const N: usize = 12;
    const ROUNDS: usize = 30;
    let q = quadratic(92, N, 40);
    let x0 = vec![1.0f32; 40];
    let opts = RunOptions { rounds: ROUNDS, eval_every: 10, seed: 11, ..Default::default() };
    let spec = ScenarioSpec { drop: 0.3, ..Default::default() };
    let mk = || {
        Driver::new()
            .with_up(Box::new(fedeff::compress::topk::TopK::new(5)))
            .with_up_edge(1, Box::new(fedeff::compress::topk::TopK::new(10)))
            .with_topology(Topology::Tree(AggTree::even(N, &[3], vec![0.05, 1.0])))
    };
    let mut a = FedAvg::new(2, 0.1);
    let rec_timed = mk().run_scenario(&mut a, &q, &spec, &x0, &opts).unwrap();
    let stat = rec_timed.scenario.expect("scenario stat");
    assert!(stat.dropped > 0, "dropout profile never fired: {stat:?}");
    assert_eq!(stat.applies as usize, ROUNDS, "every round must complete");

    // replay the engine's cohort trimming from its public streams:
    // unavailability is 0 (no coin), compute draws live on their own
    // stream, so survival is exactly the EV_DROP coin per (round, client)
    let survivors: VecDeque<Vec<usize>> = (0..ROUNDS)
        .map(|t| {
            (0..N)
                .filter(|&c| !event_rng(opts.seed, t, c, EV_DROP).bernoulli(spec.drop))
                .collect()
        })
        .collect();
    let total_survivors: usize = survivors.iter().map(|s| s.len()).sum();
    assert_eq!(
        total_survivors as u64 + stat.dropped,
        (N * ROUNDS) as u64,
        "recomputed survivor cohorts disagree with the engine"
    );
    let scripted = ScriptedSampler { n: N, rounds: RefCell::new(survivors) };
    let mut b = FedAvg::new(2, 0.1);
    let rec_ref =
        mk().with_sampler(Box::new(scripted)).run(&mut b, &q, &x0, &opts).unwrap();
    // bit-for-bit: losses (partial hubs aggregated with survivor-cohort
    // weighting), booked bits on every link and edge class (only what
    // survivors sent), comm cost
    assert_records_eq(&rec_ref, &rec_timed, false, "tree dropout vs scripted reference");
}

fn straggler_spec(mode: Mode) -> ScenarioSpec {
    ScenarioSpec {
        compute: Dist::Pareto { scale: 0.05, shape: 1.1 },
        mode,
        ..Default::default()
    }
}

/// The headline claim: under a heavy-tailed straggler profile,
/// buffered-async aggregation reaches the sync run's mid-run loss in
/// strictly less virtual time (the barrier pays the slowest of all n
/// clients every round; the async server applies every `buffer`
/// arrivals and never waits for the tail).
#[test]
fn async_reaches_target_loss_in_less_virtual_time_than_sync() {
    let q = quadratic(93, 16, 12);
    let x0 = vec![1.0f32; 12];
    let sync_opts = RunOptions { rounds: 30, eval_every: 1, seed: 5, ..Default::default() };
    let mut a = FedAvg::new(2, 0.1);
    let rec_sync = Driver::new()
        .run_scenario(&mut a, &q, &straggler_spec(Mode::Sync), &x0, &sync_opts)
        .unwrap();
    // target: the sync run's loss a third of the way in, and the virtual
    // time sync itself needed to first reach it
    let target = rec_sync.rounds[10].loss;
    let sync_vtime = rec_sync
        .rounds
        .iter()
        .find(|r| r.loss <= target)
        .expect("sync run never reached its own loss")
        .vtime;
    assert!(sync_vtime > 0.0);

    let async_opts = RunOptions { rounds: 120, eval_every: 1, seed: 5, ..Default::default() };
    let spec = straggler_spec(Mode::BufferedAsync {
        buffer: 4,
        staleness: Staleness::Poly(0.5),
    });
    let mut b = FedAvg::new(2, 0.1);
    let rec_async = Driver::new().run_scenario(&mut b, &q, &spec, &x0, &async_opts).unwrap();
    let async_vtime = rec_async
        .rounds
        .iter()
        .find(|r| r.loss <= target)
        .unwrap_or_else(|| panic!("async run never reached sync target {target}"))
        .vtime;
    assert!(
        async_vtime < sync_vtime,
        "buffered-async must beat the barrier: async {async_vtime} vs sync {sync_vtime} \
         virtual s to loss {target}"
    );
    let stat = rec_async.scenario.expect("scenario stat");
    assert_eq!(stat.applies, 120);
    assert!(stat.dispatches >= stat.applies * 4, "4 arrivals per apply");
}

/// Same seed => bitwise identical buffered-async run: losses, booked
/// bits, virtual timestamps, final stat.
#[test]
fn async_same_seed_replays_bitwise() {
    let q = quadratic(94, 10, 16);
    let x0 = vec![2.0f32; 16];
    let opts = RunOptions { rounds: 60, eval_every: 5, seed: 21, ..Default::default() };
    let spec = ScenarioSpec {
        compute: Dist::Exp { mean: 0.4 },
        speed: Dist::Uniform { lo: 0.5, hi: 2.0 },
        drop: 0.1,
        mode: Mode::BufferedAsync { buffer: 3, staleness: Staleness::Constant(0.8) },
        ..Default::default()
    };
    let mk = || Driver::new().with_up(Box::new(fedeff::compress::topk::TopK::new(4)));
    let mut a = FedAvg::new(2, 0.1);
    let rec_a = mk().run_scenario(&mut a, &q, &spec, &x0, &opts).unwrap();
    let mut b = FedAvg::new(2, 0.1);
    let rec_b = mk().run_scenario(&mut b, &q, &spec, &x0, &opts).unwrap();
    assert_records_eq(&rec_a, &rec_b, true, "async replay");
    assert_eq!(rec_a.scenario, rec_b.scenario, "async replay stat");
    let stat = rec_a.scenario.expect("scenario stat");
    // dropped in-flight updates booked no uplink bits but did redispatch
    assert!(stat.dropped > 0, "drop profile never fired: {stat:?}");
    assert!(stat.dispatches > stat.applies * 3, "dropped arrivals still redispatch");
}

/// Unsupported async configurations fail loudly, before any work runs.
#[test]
fn async_guards_are_loud() {
    let q = quadratic(95, 16, 8);
    let x0 = vec![1.0f32; 8];
    let opts = RunOptions { rounds: 5, eval_every: 5, seed: 1, ..Default::default() };
    let spec = |buffer| {
        straggler_spec(Mode::BufferedAsync { buffer, staleness: Staleness::Poly(0.5) })
    };
    // algorithm without an async absorb hook (Scaffold's control pair)
    let mut sca = Scaffold::new(3, 0.05);
    let e = Driver::new()
        .run_scenario(&mut sca, &q, &spec(4), &x0, &opts)
        .unwrap_err()
        .to_string();
    assert!(e.contains("does not support buffered-async"), "{e}");
    // cohort samplers are a barrier concept
    let mut f = FedAvg::new(2, 0.1);
    let e = Driver::new()
        .with_sampler(Box::new(NiceSampling { n: 16, tau: 4 }))
        .run_scenario(&mut f, &q, &spec(4), &x0, &opts)
        .unwrap_err()
        .to_string();
    assert!(e.contains("drop the cohort sampler"), "{e}");
    // buffer bounds: 0 dies in validation, > n at the entry point
    let mut f = FedAvg::new(2, 0.1);
    let e = Driver::new().run_scenario(&mut f, &q, &spec(0), &x0, &opts).unwrap_err().to_string();
    assert!(e.contains("async buffer size must be > 0"), "{e}");
    let mut f = FedAvg::new(2, 0.1);
    let e = Driver::new().run_scenario(&mut f, &q, &spec(17), &x0, &opts).unwrap_err().to_string();
    assert!(e.contains("async buffer size must be in 1..=16"), "{e}");
    // non-flat topologies are sync-only
    let mut f = FedAvg::new(2, 0.1);
    let e = Driver::new()
        .with_topology(Topology::Hier(fedeff::coordinator::hierarchy::Hierarchy::even(
            16, 4, 0.05, 1.0,
        )))
        .run_scenario(&mut f, &q, &spec(4), &x0, &opts)
        .unwrap_err()
        .to_string();
    assert!(e.contains("only the flat topology"), "{e}");
}
