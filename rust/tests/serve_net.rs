//! The networked coordinator's acceptance bar (DESIGN.md §Wire): a
//! `NetServer` + socket client fleet run of a spec reproduces the
//! in-process fused driver run of the same spec **bit for bit** —
//! identical loss raw bits, identical booked `bits_up` / `bits_down`,
//! identical comm cost — across the wire-eligible configurations
//! (sparse compressors, masked raw, masked compressed, local steps,
//! cohort sampling). Plus the robustness contract: malformed, truncated
//! and oversized frames error loudly and never hang the server. Under
//! `[faults] quorum` the bar extends to fault tolerance (DESIGN.md
//! §Faults): a quorum-completed round with cohort members lost mid-run
//! must match the in-process `run_scenario_scripted` run that scripts
//! the same clients as departed — bit for bit, at 1024 connections.

use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use fedeff::algorithms::{build_algorithm, RunOptions};
use fedeff::config::{build_driver, build_faults, build_scenario, Spec};
use fedeff::metrics::RunRecord;
use fedeff::scenario::{FaultScript, ScenarioSpec};
use fedeff::wire::net::{
    fleet_oracle, run_fleet, run_fleet_clients, run_fleet_faulty, run_fleet_reconnecting,
    run_in_process, NetServer,
};

/// Serve `spec` on an already-bound server with an in-thread fleet,
/// then run the same spec in-process; return both records.
fn serve_pair(spec: &Spec, server: &NetServer) -> (RunRecord, RunRecord) {
    let addr = server.local_addr().expect("resolved address");
    let net = std::thread::scope(|scope| {
        let fleet = {
            let addr = addr.clone();
            scope.spawn(move || run_fleet(&addr, spec))
        };
        let rec = server.serve(spec, &mut |_| {}).expect("networked serve");
        fleet.join().expect("fleet thread").expect("fleet run");
        rec
    });
    let inproc = run_in_process(spec, &mut |_| {}).expect("in-process run");
    (net, inproc)
}

/// Run `toml` once over TCP loopback (server + in-thread fleet) and
/// once in-process; return both records.
fn networked_vs_inproc(toml: &str) -> (RunRecord, RunRecord) {
    let spec = Spec::parse(toml).expect("test spec parses");
    let server = NetServer::bind("tcp:127.0.0.1:0").expect("bind loopback");
    serve_pair(&spec, &server)
}

fn assert_bitwise_equal(net: &RunRecord, inproc: &RunRecord) {
    assert_eq!(net.rounds.len(), inproc.rounds.len(), "eval round counts differ");
    assert!(!net.rounds.is_empty(), "run produced no eval rounds");
    for (a, b) in net.rounds.iter().zip(&inproc.rounds) {
        assert_eq!(a.round, b.round);
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "round {}: networked loss {} != in-process loss {}",
            a.round,
            a.loss,
            b.loss
        );
        assert_eq!(a.bits_up, b.bits_up, "round {}: booked uplink bits differ", a.round);
        assert_eq!(a.bits_down, b.bits_down, "round {}: booked downlink bits differ", a.round);
        assert_eq!(
            a.comm_cost.to_bits(),
            b.comm_cost.to_bits(),
            "round {}: comm cost differs",
            a.round
        );
    }
    assert_eq!(net.mask_nnz, inproc.mask_nnz, "mask support sizes differ");
}

#[test]
fn gd_topk_over_tcp_matches_inproc_bitwise() {
    let (net, inproc) = networked_vs_inproc(
        r#"
[experiment]
name = "net-gd-topk"
rounds = 20
eval_every = 5
seed = 7

[dataset]
clients = 8

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 12
"#,
    );
    assert_bitwise_equal(&net, &inproc);
    // compression actually happened: bits stay far below dense
    let last = net.rounds.last().unwrap();
    assert!(last.bits_up > 0 && last.bits_up < 20 * 32 * 112);
}

#[test]
fn fedavg_sampled_randk_over_tcp_matches_inproc_bitwise() {
    // local steps (LocalSgd payload) + the default nice sampler
    // (changing cohorts each round) + rand-k's per-client rng streams
    let (net, inproc) = networked_vs_inproc(
        r#"
[experiment]
name = "net-fedavg-randk"
rounds = 18
eval_every = 6
seed = 3

[dataset]
clients = 12

[algorithm]
kind = "fedavg"
local_steps = 3
lr = 0.1

[compressor]
up = "rand-k"
k = 16
"#,
    );
    assert_bitwise_equal(&net, &inproc);
}

#[test]
fn fedprox_srandk_over_tcp_matches_inproc_bitwise() {
    // proximal local steps (prox_mu travels in the ROUND frame)
    let (net, inproc) = networked_vs_inproc(
        r#"
[experiment]
name = "net-fedprox-srandk"
rounds = 12
eval_every = 4
seed = 11

[dataset]
clients = 10

[algorithm]
kind = "fedprox"
local_steps = 2
lr = 0.1
mu_prox = 0.05

[compressor]
up = "srand-k"
k = 10
"#,
    );
    assert_bitwise_equal(&net, &inproc);
}

#[test]
fn masked_compressed_uplink_over_tcp_matches_inproc_bitwise() {
    // global sparsity mask + top-k within the support: the
    // MaskedSparse layout with support-relative packed indices
    let (net, inproc) = networked_vs_inproc(
        r#"
[experiment]
name = "net-masked-topk"
rounds = 16
eval_every = 4
seed = 5

[dataset]
clients = 8

[algorithm]
kind = "fedavg"
local_steps = 2
lr = 0.1

[compressor]
up = "top-k"
k = 8

[sparsity]
method = "magnitude"
sparsity = 0.5
"#,
    );
    assert_bitwise_equal(&net, &inproc);
    assert!(net.mask_nnz.is_some(), "masked run must report its support");
}

#[test]
fn masked_raw_uplink_over_tcp_matches_inproc_bitwise() {
    // mask with no compressor: the MaskedRaw layout (values only,
    // 32 bits per support coordinate)
    let (net, inproc) = networked_vs_inproc(
        r#"
[experiment]
name = "net-masked-raw"
rounds = 12
eval_every = 4
seed = 9

[dataset]
clients = 6

[algorithm]
kind = "gd"
lr = 0.5

[sparsity]
method = "magnitude"
sparsity = 0.6
"#,
    );
    assert_bitwise_equal(&net, &inproc);
}

// -------------------------------------------------------------------
// robustness: broken peers error loudly, never hang or panic
// -------------------------------------------------------------------

const BROKEN_PEER_SPEC: &str = r#"
[experiment]
name = "net-broken"
rounds = 5
seed = 1

[dataset]
clients = 1

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 4
"#;

/// Bind a short-timeout server and run `peer` against it on a raw TCP
/// socket; the serve must return an error (and must return at all).
fn serve_against_broken_peer(peer: impl FnOnce(&mut TcpStream) + Send) -> String {
    let spec = Spec::parse(BROKEN_PEER_SPEC).unwrap();
    let mut server = NetServer::bind("tcp:127.0.0.1:0").unwrap();
    server.timeout = Duration::from_millis(500);
    let addr = server.local_addr().unwrap();
    let hostport = addr.strip_prefix("tcp:").unwrap().to_string();
    std::thread::scope(|scope| {
        scope.spawn(move || {
            let mut s = TcpStream::connect(&hostport).expect("connect to test server");
            peer(&mut s);
            // hold the socket open briefly so the server error comes
            // from frame validation, not a racing disconnect
            std::thread::sleep(Duration::from_millis(200));
        });
        let err = server
            .serve(&spec, &mut |_| {})
            .expect_err("server must reject the broken peer");
        format!("{err:#}")
    })
}

fn frame(kind: u8, payload: &[u8]) -> Vec<u8> {
    let mut f = Vec::with_capacity(5 + payload.len());
    f.extend_from_slice(&(payload.len() as u32 + 1).to_le_bytes());
    f.push(kind);
    f.extend_from_slice(payload);
    f
}

#[test]
fn garbage_first_frame_errors_loudly() {
    let err = serve_against_broken_peer(|s| {
        s.write_all(&frame(0xAB, &[1, 2, 3])).unwrap();
    });
    assert!(err.contains("HELLO"), "unexpected error: {err}");
}

#[test]
fn oversized_frame_is_rejected() {
    let err = serve_against_broken_peer(|s| {
        // header claims 1 GiB; the length check must fire before any
        // allocation or read of that size
        s.write_all(&(1u32 << 30).to_le_bytes()).unwrap();
        s.write_all(&[1]).unwrap();
    });
    assert!(err.contains("oversized"), "unexpected error: {err}");
}

#[test]
fn truncated_frame_times_out_with_an_error() {
    let err = serve_against_broken_peer(|s| {
        // header promises 64 payload bytes that never arrive; the read
        // timeout must surface as an error instead of hanging
        s.write_all(&65u32.to_le_bytes()).unwrap();
        s.write_all(&[1]).unwrap();
    });
    assert!(!err.is_empty());
}

#[test]
fn malformed_msg_after_valid_hello_errors_loudly() {
    let err = serve_against_broken_peer(|s| {
        // a correct HELLO for client 0 of 1 (dim 112 = mushrooms) ...
        let mut hello = Vec::new();
        hello.extend_from_slice(&0u32.to_le_bytes());
        hello.extend_from_slice(&1u32.to_le_bytes());
        hello.extend_from_slice(&112u32.to_le_bytes());
        s.write_all(&frame(1, &hello)).unwrap();
        // ... then an MSG whose body length cannot match any layout
        let mut msg = Vec::new();
        msg.extend_from_slice(&0u32.to_le_bytes()); // round
        msg.push(0); // channel
        msg.push(0); // layout: sparse
        msg.extend_from_slice(&4u32.to_le_bytes()); // k = 4
        msg.extend_from_slice(&[0xFF; 3]); // 3 bytes << the 20 required
        s.write_all(&frame(3, &msg)).unwrap();
    });
    assert!(err.contains("decoding client 0"), "unexpected error: {err}");
}

#[test]
fn duplicate_client_id_is_rejected() {
    let spec = Spec::parse(
        r#"
[experiment]
name = "net-dup"
rounds = 3
seed = 1

[dataset]
clients = 2

[algorithm]
kind = "gd"

[compressor]
up = "top-k"
k = 4
"#,
    )
    .unwrap();
    let mut server = NetServer::bind("tcp:127.0.0.1:0").unwrap();
    server.timeout = Duration::from_millis(500);
    let addr = server.local_addr().unwrap();
    let hostport = addr.strip_prefix("tcp:").unwrap().to_string();
    std::thread::scope(|scope| {
        scope.spawn(move || {
            let mut hello = Vec::new();
            hello.extend_from_slice(&0u32.to_le_bytes());
            hello.extend_from_slice(&2u32.to_le_bytes());
            hello.extend_from_slice(&112u32.to_le_bytes());
            let f = frame(1, &hello);
            // two sockets both claiming client id 0
            let mut a = TcpStream::connect(&hostport).unwrap();
            a.write_all(&f).unwrap();
            let mut b = TcpStream::connect(&hostport).unwrap();
            b.write_all(&f).unwrap();
            std::thread::sleep(Duration::from_millis(200));
        });
        let err = server.serve(&spec, &mut |_| {}).expect_err("duplicate id must be rejected");
        assert!(format!("{err:#}").contains("twice"), "unexpected error: {err:#}");
    });
}

// -------------------------------------------------------------------
// event-loop scaling: the bit-for-bit contract holds at 1024 clients
// -------------------------------------------------------------------

/// The acceptance bar of the event-driven rewrite: a 1024-connection
/// fleet over a Unix domain socket reproduces the in-process run bit
/// for bit. Exercises partial-frame reassembly, arrival-order decode
/// and cohort-order commit under real kernel scheduling pressure.
#[cfg(unix)]
#[test]
fn evloop_1024_clients_gd_topk_match_inproc_bitwise() {
    let limit = fedeff::wire::evloop::raise_nofile_limit();
    assert!(limit >= 3500, "need ~3 fds per client; soft limit stuck at {limit}");
    let spec = Spec::parse(
        r#"
[experiment]
name = "net-evloop-1024"
rounds = 4
eval_every = 2
seed = 42

[dataset]
clients = 1024

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 8
"#,
    )
    .unwrap();
    let path = std::env::temp_dir().join(format!("fedeff-evloop-{}.sock", std::process::id()));
    let server = NetServer::bind(&format!("uds:{}", path.display())).expect("bind uds");
    let (net, inproc) = serve_pair(&spec, &server);
    assert_bitwise_equal(&net, &inproc);
    let stats = server.stats();
    // (`connected` may already have ticked down for clients that read
    // their DONE and hung up while the shutdown flush was pumping)
    assert_eq!(stats.evicted, 0, "no fleet member may be evicted");
    // 4 rounds x 1024 clients x 1 channel, each decoded exactly once
    assert_eq!(stats.frames_in, 4 * 1024, "arrival-order staging lost or duplicated frames");
    assert!(stats.bytes_in > 0 && stats.bytes_out > 0);
}

// -------------------------------------------------------------------
// adversarial connections: trickle, silence, disconnects, churn
// -------------------------------------------------------------------

/// Frames delivered one byte at a time must reassemble exactly as if
/// they had arrived whole — including reassembling a *malformed* MSG
/// whose decode must then fail as loudly as the fast path.
#[test]
fn trickled_frames_reassemble_across_reads() {
    let err = serve_against_broken_peer(|s| {
        let mut hello = Vec::new();
        hello.extend_from_slice(&0u32.to_le_bytes());
        hello.extend_from_slice(&1u32.to_le_bytes());
        hello.extend_from_slice(&112u32.to_le_bytes());
        for &b in &frame(1, &hello) {
            s.write_all(&[b]).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(2));
        }
        // a reassembled-but-undecodable MSG: 3 body bytes where the
        // sparse layout with k = 4 packs 20
        let mut msg = Vec::new();
        msg.extend_from_slice(&0u32.to_le_bytes());
        msg.push(0);
        msg.push(0);
        msg.extend_from_slice(&4u32.to_le_bytes());
        msg.extend_from_slice(&[0xFF; 3]);
        for &b in &frame(3, &msg) {
            s.write_all(&[b]).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(1));
        }
    });
    assert!(err.contains("decoding client 0"), "unexpected error: {err}");
}

/// A connection that never says HELLO must not stall the fleet: the
/// real clients join and the run completes bit-for-bit while the
/// silent socket is shed on its own.
#[test]
fn silent_connection_never_stalls_the_fleet() {
    let spec = Spec::parse(
        r#"
[experiment]
name = "net-silent"
rounds = 6
eval_every = 2
seed = 13

[dataset]
clients = 2

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 6
"#,
    )
    .unwrap();
    let server = NetServer::bind("tcp:127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let hostport = addr.strip_prefix("tcp:").unwrap().to_string();
    // connect the silent peer BEFORE the fleet so it is guaranteed to
    // occupy a pending slot while the real clients join around it
    let silent = TcpStream::connect(&hostport).expect("silent connect");
    let net = std::thread::scope(|scope| {
        let fleet = {
            let spec = &spec;
            let addr = addr.clone();
            scope.spawn(move || run_fleet(&addr, spec))
        };
        let rec = server.serve(&spec, &mut |_| {}).expect("silent peer must not break serve");
        fleet.join().expect("fleet thread").expect("fleet run");
        rec
    });
    drop(silent);
    let inproc = run_in_process(&spec, &mut |_| {}).expect("in-process run");
    assert_bitwise_equal(&net, &inproc);
    let stats = server.stats();
    assert!(
        stats.rejected + stats.evicted + stats.churned >= 1,
        "the silent connection must show up as shed in the stats"
    );
}

/// A cohort member that hangs up mid-round aborts the round loudly,
/// naming the client — and does so promptly, on the disconnect event
/// itself rather than by burning the full progress deadline.
#[test]
fn cohort_disconnect_mid_round_names_the_client() {
    let spec = Spec::parse(
        r#"
[experiment]
name = "net-disconnect"
rounds = 5
seed = 1

[dataset]
clients = 2

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 4
"#,
    )
    .unwrap();
    let mut server = NetServer::bind("tcp:127.0.0.1:0").unwrap();
    server.timeout = Duration::from_secs(2);
    let addr = server.local_addr().unwrap();
    let hostport = addr.strip_prefix("tcp:").unwrap().to_string();
    std::thread::scope(|scope| {
        scope.spawn(move || {
            // a valid HELLO for client 1, then vanish mid-round
            let mut hello = Vec::new();
            hello.extend_from_slice(&1u32.to_le_bytes());
            hello.extend_from_slice(&2u32.to_le_bytes());
            hello.extend_from_slice(&112u32.to_le_bytes());
            let mut s = TcpStream::connect(&hostport).expect("connect");
            s.write_all(&frame(1, &hello)).unwrap();
            std::thread::sleep(Duration::from_millis(100));
        });
        let fleet = {
            let spec = &spec;
            let addr = addr.clone();
            scope.spawn(move || run_fleet_clients(&addr, spec, &[0]))
        };
        let t0 = Instant::now();
        let err = server.serve(&spec, &mut |_| {}).expect_err("disconnect must abort the round");
        let elapsed = t0.elapsed();
        let _ = fleet.join(); // client 0 errors once the server hangs up
        let msg = format!("{err:#}");
        assert!(msg.contains("client 1"), "error must name the client: {msg}");
        assert!(
            elapsed < Duration::from_secs(3),
            "disconnect must surface on the event, not a timeout sweep ({elapsed:?})"
        );
    });
}

/// A cohort member that stays connected but never answers is evicted
/// on *its own* progress deadline — once, not once per peer — while
/// every other connection's frames keep landing in the staging area.
#[test]
fn stalled_client_is_evicted_while_others_progress() {
    let spec = Spec::parse(
        r#"
[experiment]
name = "net-stall"
rounds = 5
seed = 2

[dataset]
clients = 4

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 4
"#,
    )
    .unwrap();
    let mut server = NetServer::bind("tcp:127.0.0.1:0").unwrap();
    server.timeout = Duration::from_millis(800);
    let addr = server.local_addr().unwrap();
    let hostport = addr.strip_prefix("tcp:").unwrap().to_string();
    std::thread::scope(|scope| {
        scope.spawn(move || {
            // client 3 joins, receives its ROUND, and goes catatonic
            let mut hello = Vec::new();
            hello.extend_from_slice(&3u32.to_le_bytes());
            hello.extend_from_slice(&4u32.to_le_bytes());
            hello.extend_from_slice(&112u32.to_le_bytes());
            let mut s = TcpStream::connect(&hostport).expect("connect");
            s.write_all(&frame(1, &hello)).unwrap();
            std::thread::sleep(Duration::from_secs(2));
        });
        let fleet = {
            let spec = &spec;
            let addr = addr.clone();
            scope.spawn(move || run_fleet_clients(&addr, spec, &[0, 1, 2]))
        };
        let t0 = Instant::now();
        let err = server.serve(&spec, &mut |_| {}).expect_err("stall must abort the round");
        let elapsed = t0.elapsed();
        let _ = fleet.join();
        let msg = format!("{err:#}");
        assert!(msg.contains("client 3") && msg.contains("stalled"), "unexpected error: {msg}");
        // one deadline, not one per awaited connection: well under the
        // 4 x timeout a serial per-client wait would burn
        assert!(
            elapsed >= Duration::from_millis(700) && elapsed < Duration::from_millis(2500),
            "eviction must fire on the stalled client's own deadline ({elapsed:?})"
        );
        // the healthy clients' messages were decoded and staged while
        // client 3 sat on the clock
        let stats = server.stats();
        assert!(
            stats.frames_in >= 3,
            "other connections must make decode progress during the stall \
             (saw {} frames)",
            stats.frames_in
        );
    });
}

/// Connect/disconnect churn against the listener — before and during
/// the rounds — never perturbs the run: churned sockets are shed and
/// the fleet's result stays bit-for-bit.
#[test]
fn connect_disconnect_churn_leaves_the_run_bitwise_intact() {
    let spec = Spec::parse(
        r#"
[experiment]
name = "net-churn"
rounds = 10
eval_every = 5
seed = 21

[dataset]
clients = 3

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 8
"#,
    )
    .unwrap();
    let server = NetServer::bind("tcp:127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let hostport = addr.strip_prefix("tcp:").unwrap().to_string();
    let net = std::thread::scope(|scope| {
        scope.spawn(move || {
            for _ in 0..40 {
                // connect, say nothing, hang up (late cycles may race
                // server shutdown — a refused connect is fine)
                let _ = TcpStream::connect(&hostport);
                std::thread::sleep(Duration::from_millis(2));
            }
        });
        let fleet = {
            let spec = &spec;
            let addr = addr.clone();
            scope.spawn(move || run_fleet(&addr, spec))
        };
        let rec = server.serve(&spec, &mut |_| {}).expect("churn must not break serve");
        fleet.join().expect("fleet thread").expect("fleet run");
        rec
    });
    let inproc = run_in_process(&spec, &mut |_| {}).expect("in-process run");
    assert_bitwise_equal(&net, &inproc);
}

// -------------------------------------------------------------------
// sparse delta broadcast: the pipelined downlink stays bit-for-bit
// -------------------------------------------------------------------

const DELTA_SPEC: &str = r#"
[experiment]
name = "net-delta"
rounds = 18
eval_every = 6
seed = 3

[dataset]
clients = 12

[algorithm]
kind = "fedavg"
local_steps = 3
lr = 0.1
sampler = "nice"
tau = 3

[compressor]
up = "top-k"
k = 4
downlink = "delta"
"#;

/// `downlink = "delta"` over TCP: the per-variant anchor-delta frames
/// (including dense resyncs forced by the changing nice cohorts)
/// reproduce the in-process delta run bit for bit — losses, booked
/// bits, comm cost.
#[test]
fn sync_delta_downlink_over_tcp_matches_inproc_bitwise() {
    let (net, inproc) = networked_vs_inproc(DELTA_SPEC);
    assert_bitwise_equal(&net, &inproc);
}

/// The delta downlink is exact (identical losses to the dense
/// broadcast of the same spec) while booking strictly fewer downlink
/// bits once the per-round change set is O(cohort * k).
#[test]
fn delta_downlink_is_exact_and_cheaper_than_dense() {
    let delta_spec = Spec::parse(DELTA_SPEC).unwrap();
    let dense_spec = Spec::parse(&DELTA_SPEC.replace("downlink = \"delta\"\n", "")).unwrap();
    assert!(dense_spec.links.downlink.is_none(), "dense control spec still names a downlink");
    let delta = run_in_process(&delta_spec, &mut |_| {}).expect("delta run");
    let dense = run_in_process(&dense_spec, &mut |_| {}).expect("dense run");
    assert_eq!(delta.rounds.len(), dense.rounds.len());
    for (a, b) in delta.rounds.iter().zip(&dense.rounds) {
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "round {}: the delta broadcast must be exact",
            a.round
        );
        assert_eq!(a.bits_up, b.bits_up, "round {}: the uplink is untouched", a.round);
    }
    let (a, b) = (delta.rounds.last().unwrap(), dense.rounds.last().unwrap());
    assert!(
        a.bits_down < b.bits_down,
        "delta downlink must beat dense: {} >= {} bits after {} rounds",
        a.bits_down,
        b.bits_down,
        a.round
    );
}

// -------------------------------------------------------------------
// pipelined broadcast: late straggler frames are discarded, not decoded
// -------------------------------------------------------------------

/// Read one `len | kind | payload` frame off a blocking socket.
fn read_frame(s: &mut TcpStream) -> (u8, Vec<u8>) {
    use std::io::Read;
    let mut len = [0u8; 4];
    s.read_exact(&mut len).expect("frame length");
    let len = u32::from_le_bytes(len) as usize;
    let mut kind = [0u8; 1];
    s.read_exact(&mut kind).expect("frame kind");
    let mut payload = vec![0u8; len - 1];
    s.read_exact(&mut payload).expect("frame payload");
    (kind[0], payload)
}

/// A valid sparse MSG frame echoing `round`: k strictly-ascending
/// coordinates bit-packed exactly as the negotiated layout demands.
fn sparse_msg(round: u32, k: usize, dim: usize) -> Vec<u8> {
    use fedeff::compress::SparseVec;
    use fedeff::wire::bits::BitWriter;
    use fedeff::wire::codec;
    let mut sv = SparseVec::default();
    sv.dim = dim;
    for i in 0..k {
        sv.push((i * 2) as u32, 0.125 * (i as f32 + 1.0));
    }
    let mut w = BitWriter::new();
    codec::encode_sparse(&sv, &mut w).expect("encode sparse body");
    let mut msg = Vec::new();
    msg.extend_from_slice(&round.to_le_bytes());
    msg.push(0); // channel
    msg.push(0); // layout: sparse
    msg.extend_from_slice(&(k as u32).to_le_bytes());
    msg.extend_from_slice(w.finish());
    frame(3, &msg)
}

/// A straggler MSG racing the pipelined next-round broadcast: the
/// protocol-speaking client answers round 0, reads ROUND 1 (so the
/// server has definitively committed and moved on), then replays its
/// round-0 answer before the real one. The stale frame must be
/// consumed and discarded (`stale_discarded`), never decoded into
/// round 1, and the serve must complete.
#[test]
fn late_straggler_frame_is_discarded_not_decoded() {
    let spec = Spec::parse(
        r#"
[experiment]
name = "net-stale"
rounds = 3
seed = 1

[dataset]
clients = 1

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 4
"#,
    )
    .unwrap();
    let server = NetServer::bind("tcp:127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let hostport = addr.strip_prefix("tcp:").unwrap().to_string();
    std::thread::scope(|scope| {
        scope.spawn(move || {
            let mut s = TcpStream::connect(&hostport).expect("connect");
            let mut hello = Vec::new();
            hello.extend_from_slice(&0u32.to_le_bytes());
            hello.extend_from_slice(&1u32.to_le_bytes());
            hello.extend_from_slice(&112u32.to_le_bytes());
            s.write_all(&frame(1, &hello)).unwrap();
            loop {
                let (kind, payload) = read_frame(&mut s);
                if kind == 4 {
                    break; // DONE
                }
                assert_eq!(kind, 2, "expected ROUND frame");
                let round = u32::from_le_bytes(payload[..4].try_into().unwrap());
                if round == 1 {
                    // the server is provably on round 1; replay round 0
                    s.write_all(&sparse_msg(0, 4, 112)).unwrap();
                }
                s.write_all(&sparse_msg(round, 4, 112)).unwrap();
            }
        });
        server.serve(&spec, &mut |_| {}).expect("stale frame must not break the serve");
    });
    let stats = server.stats();
    assert_eq!(stats.stale_discarded, 1, "exactly the replayed frame is discarded");
    assert_eq!(stats.frames_in, 3, "each round decoded exactly once");
    assert!(
        stats.max_queue_depth >= 1,
        "the pipelined broadcast must have queued frames ({:?})",
        stats.max_queue_depth
    );
}

// -------------------------------------------------------------------
// buffered-async over the wire
// -------------------------------------------------------------------

const ASYNC_SPEC: &str = r#"
[experiment]
name = "net-async"
rounds = 12
eval_every = 4
seed = 17

[dataset]
clients = 6

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 8

[scenario]
compute = "uniform(0.01, 0.05)"
speed = "uniform(0.5, 2.0)"
bandwidth = 100000.0
drop = 0.1
mode = "async"
buffer = 3
staleness = "poly(0.5)"
"#;

fn assert_scenario_equal(net: &RunRecord, inproc: &RunRecord) {
    let (a, b) = (
        net.scenario.as_ref().expect("networked scenario stats"),
        inproc.scenario.as_ref().expect("in-process scenario stats"),
    );
    assert_eq!(a.vtime.to_bits(), b.vtime.to_bits(), "virtual clocks diverged");
    assert_eq!(a.dispatches, b.dispatches);
    assert_eq!(a.applies, b.applies);
    assert_eq!(a.dropped, b.dropped);
    assert_eq!(a.unavailable, b.unavailable);
}

/// `mode = "async"` over TCP: staleness-weighted folds every `buffer`
/// arrivals, per-client redispatch, mid-flight drops — all bit-for-bit
/// the in-process virtual-clock engine, including the scenario
/// counters and the virtual clock itself.
#[test]
fn buffered_async_over_tcp_matches_inproc_bitwise() {
    let (net, inproc) = networked_vs_inproc(ASYNC_SPEC);
    assert_bitwise_equal(&net, &inproc);
    assert_scenario_equal(&net, &inproc);
}

/// Buffered-async composed with the anchor-delta downlink: per-client
/// version-stamped delta frames stay bit-for-bit, exact (same losses
/// as the dense-downlink async run) and cheaper on the downlink.
#[test]
fn buffered_async_delta_downlink_matches_inproc_bitwise() {
    let toml = ASYNC_SPEC.replace("k = 8\n", "k = 8\ndownlink = \"delta\"\n");
    let (net, inproc) = networked_vs_inproc(&toml);
    assert_bitwise_equal(&net, &inproc);
    assert_scenario_equal(&net, &inproc);
    // exactness + the O(k) claim, against the dense async run
    let dense = run_in_process(&Spec::parse(ASYNC_SPEC).unwrap(), &mut |_| {}).unwrap();
    assert_eq!(net.rounds.len(), dense.rounds.len());
    for (a, b) in net.rounds.iter().zip(&dense.rounds) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "apply {}: delta must be exact", a.round);
        assert_eq!(a.bits_up, b.bits_up);
    }
    let (a, b) = (net.rounds.last().unwrap(), dense.rounds.last().unwrap());
    assert!(a.bits_down < b.bits_down, "delta async downlink must beat dense");
}

/// The wire's async engine refuses sync-mode scenarios loudly (the
/// virtual clock replaces the real barrier; there is no faithful
/// networked analog).
#[test]
fn sync_scenario_over_the_wire_is_rejected() {
    let toml = ASYNC_SPEC
        .replace("mode = \"async\"\n", "mode = \"sync\"\n")
        .replace("buffer = 3\n", "")
        .replace("staleness = \"poly(0.5)\"\n", "");
    let spec = Spec::parse(&toml).unwrap();
    let server = NetServer::bind("tcp:127.0.0.1:0").unwrap();
    let err = server.serve(&spec, &mut |_| {}).expect_err("sync scenarios are in-process only");
    assert!(format!("{err:#}").contains("in-process"), "unexpected error: {err:#}");
}

/// The event-loop scaling bar for the async engine: a 1024-connection
/// buffered-async fleet over a Unix domain socket reproduces the
/// in-process virtual-clock run bit for bit.
#[cfg(unix)]
#[test]
fn evloop_1024_clients_buffered_async_match_inproc_bitwise() {
    let limit = fedeff::wire::evloop::raise_nofile_limit();
    assert!(limit >= 3500, "need ~3 fds per client; soft limit stuck at {limit}");
    let spec = Spec::parse(
        r#"
[experiment]
name = "net-evloop-async-1024"
rounds = 2
eval_every = 1
seed = 29

[dataset]
clients = 1024

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 8
downlink = "delta"

[scenario]
compute = "uniform(0.01, 0.05)"
speed = "uniform(0.5, 2.0)"
bandwidth = 100000.0
drop = 0.05
mode = "async"
buffer = 128
staleness = "poly(0.5)"
"#,
    )
    .unwrap();
    let path =
        std::env::temp_dir().join(format!("fedeff-evloop-async-{}.sock", std::process::id()));
    let server = NetServer::bind(&format!("uds:{}", path.display())).expect("bind uds");
    let (net, inproc) = serve_pair(&spec, &server);
    assert_bitwise_equal(&net, &inproc);
    assert_scenario_equal(&net, &inproc);
    let stats = server.stats();
    assert_eq!(stats.evicted, 0, "no fleet member may be evicted");
    assert!(stats.bytes_in > 0 && stats.bytes_out > 0);
}

// -------------------------------------------------------------------
// fault tolerance: quorum-complete rounds, reconnect/resume
// -------------------------------------------------------------------

/// The same spec's deaths, run in-process: translate the fleet's
/// `(client, dies_after)` script into a [`FaultScript`] and drive
/// `Driver::run_scenario_scripted` — the bit-for-bit reference a
/// quorum-completed networked run is pinned against (DESIGN.md
/// §Faults).
fn run_scripted_inproc(spec: &Spec, scen: &ScenarioSpec, deaths: &[(usize, usize)]) -> RunRecord {
    let oracle = fleet_oracle(spec).expect("oracle");
    let d = oracle.dim();
    let mut alg = build_algorithm(&spec.algorithm, &oracle).expect("algorithm");
    let driver = build_driver(spec, spec.dataset.clients).expect("driver");
    let script = FaultScript { departures: deaths.iter().map(|&(c, r)| (r, c)).collect() };
    let opts = RunOptions {
        rounds: spec.experiment.rounds,
        eval_every: spec.experiment.eval_every,
        seed: spec.experiment.seed,
        ..Default::default()
    };
    driver
        .run_scenario_scripted(alg.as_mut(), &oracle, scen, &script, &vec![0.5f32; d], &opts)
        .expect("scripted in-process run")
}

const QUORUM_1024_SPEC: &str = r#"
[experiment]
name = "net-quorum-1024"
rounds = 4
eval_every = 2
seed = 42

[dataset]
clients = 1024

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 8

[faults]
quorum = 0.9
"#;

/// The quorum acceptance bar: a 1024-connection fleet where three
/// clients hang up mid-round commits every round at quorum and
/// reproduces — bit for bit — the in-process scripted run that departs
/// the same clients at the same rounds. Losses, booked uplink and
/// downlink bits, comm cost: a quorum-skipped client is *exactly* a
/// scenario-engine mid-round dropout.
#[cfg(unix)]
#[test]
fn quorum_1024_deaths_match_scripted_inproc_bitwise() {
    let limit = fedeff::wire::evloop::raise_nofile_limit();
    assert!(limit >= 3500, "need ~3 fds per client; soft limit stuck at {limit}");
    let spec = Spec::parse(QUORUM_1024_SPEC).unwrap();
    // (client, dies after fully reading round): two losses in round 1,
    // one more in round 2
    let deaths = [(7usize, 1usize), (300, 1), (901, 2)];
    let path = std::env::temp_dir().join(format!("fedeff-quorum-{}.sock", std::process::id()));
    let mut server = NetServer::bind(&format!("uds:{}", path.display())).expect("bind uds");
    server.quorum = build_faults(spec.faults.as_ref().expect("[faults] section")).unwrap();
    assert_eq!(server.quorum, Some(0.9), "the [faults] section must reach the server");
    let addr = server.local_addr().unwrap();
    let net = std::thread::scope(|scope| {
        let fleet = {
            let spec = &spec;
            let addr = addr.clone();
            scope.spawn(move || run_fleet_faulty(&addr, spec, &deaths))
        };
        let rec = server.serve(&spec, &mut |_| {}).expect("quorum serve");
        fleet.join().expect("fleet thread").expect("fleet run");
        rec
    });
    let inproc = run_scripted_inproc(&spec, &ScenarioSpec::default(), &deaths);
    assert_bitwise_equal(&net, &inproc);
    let stats = server.stats();
    assert_eq!(stats.quorum_rounds, 2, "rounds 1 and 2 each committed short of the cohort");
    assert_eq!(stats.evicted + stats.churned, 3, "each death is shed exactly once");
    assert_eq!(stats.reconnects, 0);
    assert_eq!(stats.resyncs, 0);
    assert_eq!(stats.faults_injected, 0, "no chaos layer on this run");
}

const QUORUM_ASYNC_1024_SPEC: &str = r#"
[experiment]
name = "net-quorum-async-1024"
rounds = 2
eval_every = 1
seed = 29

[dataset]
clients = 1024

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 8

[scenario]
compute = "uniform(0.01, 0.05)"
speed = "uniform(0.5, 2.0)"
bandwidth = 100000.0
drop = 0.05
mode = "async"
buffer = 128
staleness = "poly(0.5)"

[faults]
quorum = 0.5
"#;

/// The buffered-async half of the quorum bar at 1024 connections: two
/// clients vanish after their first dispatch, their in-flight updates
/// are lost, and the run matches the in-process scripted async engine
/// bit for bit — virtual clock, dispatch/apply/drop counters and all.
#[cfg(unix)]
#[test]
fn quorum_async_1024_deaths_match_scripted_inproc_bitwise() {
    let limit = fedeff::wire::evloop::raise_nofile_limit();
    assert!(limit >= 3500, "need ~3 fds per client; soft limit stuck at {limit}");
    let spec = Spec::parse(QUORUM_ASYNC_1024_SPEC).unwrap();
    // both victims die after fully reading dispatch 0: their first
    // flight is forever in-flight, parked at infinite arrival
    let deaths = [(3usize, 0usize), (700, 0)];
    let path =
        std::env::temp_dir().join(format!("fedeff-quorum-async-{}.sock", std::process::id()));
    let mut server = NetServer::bind(&format!("uds:{}", path.display())).expect("bind uds");
    server.quorum = build_faults(spec.faults.as_ref().expect("[faults] section")).unwrap();
    let addr = server.local_addr().unwrap();
    let net = std::thread::scope(|scope| {
        let fleet = {
            let spec = &spec;
            let addr = addr.clone();
            scope.spawn(move || run_fleet_faulty(&addr, spec, &deaths))
        };
        let rec = server.serve(&spec, &mut |_| {}).expect("quorum async serve");
        fleet.join().expect("fleet thread").expect("fleet run");
        rec
    });
    let scen = build_scenario(spec.scenario.as_ref().unwrap()).unwrap();
    let inproc = run_scripted_inproc(&spec, &scen, &deaths);
    assert_bitwise_equal(&net, &inproc);
    assert_scenario_equal(&net, &inproc);
    let stats = server.stats();
    assert_eq!(stats.evicted + stats.churned, 2, "each death is shed exactly once");
    assert_eq!(stats.reconnects, 0);
    assert_eq!(stats.resyncs, 0);
}

/// Reconnect/resume at 1024 connections with the anchor-delta
/// downlink: a client crashes after round 1, forgets its anchor
/// replica, re-dials on its backoff schedule, re-HELLOs with its id —
/// and is re-admitted at a round boundary with a dense resync (a
/// stale-round rejoin can never be patched with a delta). The run
/// completes; the books show exactly one reconnect and one resync.
#[cfg(unix)]
#[test]
fn rejoin_after_hangup_resyncs_dense_at_1024() {
    let limit = fedeff::wire::evloop::raise_nofile_limit();
    assert!(limit >= 3500, "need ~3 fds per client; soft limit stuck at {limit}");
    let spec = Spec::parse(
        r#"
[experiment]
name = "net-rejoin-1024"
rounds = 8
eval_every = 4
seed = 11

[dataset]
clients = 1024

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 8
downlink = "delta"

[faults]
quorum = 0.9
"#,
    )
    .unwrap();
    let deaths = [(37usize, 1usize)];
    let path = std::env::temp_dir().join(format!("fedeff-rejoin-{}.sock", std::process::id()));
    let mut server = NetServer::bind(&format!("uds:{}", path.display())).expect("bind uds");
    server.quorum = build_faults(spec.faults.as_ref().unwrap()).unwrap();
    let net = std::thread::scope(|scope| {
        let fleet = {
            let spec = &spec;
            let addr = server.local_addr().unwrap();
            scope.spawn(move || run_fleet_reconnecting(&addr, spec, &deaths))
        };
        let rec = server.serve(&spec, &mut |_| {}).expect("serve across the rejoin");
        fleet.join().expect("fleet thread").expect("reconnecting fleet run");
        rec
    });
    assert!(net.rounds.iter().all(|r| r.loss.is_finite()));
    let stats = server.stats();
    assert_eq!(stats.reconnects, 1, "client 37 must be re-admitted exactly once");
    assert_eq!(stats.resyncs, 1, "the rejoin must force exactly one dense resync");
    assert!(stats.quorum_rounds >= 1, "the crash round must have committed at quorum");
    assert_eq!(stats.evicted + stats.churned, 1, "one loss, no collateral churn");
}

/// A duplicate HELLO for a client whose original connection is alive
/// must be rejected — loudly, without perturbing the run. The impostor
/// dials mid-run (from the round-2 eval callback, so the timing is
/// deterministic); the fleet's result stays bit-for-bit the in-process
/// run, which also pins that a full-strength quorum round (zero
/// casualties) commits identically to a non-quorum one.
#[test]
fn duplicate_hello_for_live_client_is_rejected_mid_run() {
    let spec = Spec::parse(
        r#"
[experiment]
name = "net-dup-hello"
rounds = 10
eval_every = 1
seed = 5

[dataset]
clients = 8

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 4
"#,
    )
    .unwrap();
    let mut server = NetServer::bind("tcp:127.0.0.1:0").unwrap();
    server.quorum = Some(1.0);
    let addr = server.local_addr().unwrap();
    let hostport = addr.strip_prefix("tcp:").unwrap().to_string();
    let mut impostor: Option<TcpStream> = None;
    let net = std::thread::scope(|scope| {
        let fleet = {
            let spec = &spec;
            let addr = addr.clone();
            scope.spawn(move || run_fleet(&addr, spec))
        };
        let rec = server
            .serve(&spec, &mut |r| {
                if r.round == 2 && impostor.is_none() {
                    // client 0's original connection is alive and
                    // mid-round; this HELLO claims its id anyway
                    let mut hello = Vec::new();
                    hello.extend_from_slice(&0u32.to_le_bytes());
                    hello.extend_from_slice(&8u32.to_le_bytes());
                    hello.extend_from_slice(&112u32.to_le_bytes());
                    let mut s = TcpStream::connect(&hostport).expect("impostor connect");
                    s.write_all(&frame(1, &hello)).unwrap();
                    impostor = Some(s);
                }
            })
            .expect("the impostor must not break the serve");
        fleet.join().expect("fleet thread").expect("fleet run");
        rec
    });
    drop(impostor);
    let inproc = run_in_process(&spec, &mut |_| {}).expect("in-process run");
    assert_bitwise_equal(&net, &inproc);
    let stats = server.stats();
    assert_eq!(stats.rejected, 1, "the impostor's HELLO must be rejected exactly once");
    assert_eq!(stats.reconnects, 0, "a rejected impostor is not a reconnect");
    assert_eq!(stats.quorum_rounds, 0, "a full fleet under quorum commits complete rounds");
}
