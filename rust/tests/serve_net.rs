//! The networked coordinator's acceptance bar (DESIGN.md §Wire): a
//! `NetServer` + socket client fleet run of a spec reproduces the
//! in-process fused driver run of the same spec **bit for bit** —
//! identical loss raw bits, identical booked `bits_up` / `bits_down`,
//! identical comm cost — across the wire-eligible configurations
//! (sparse compressors, masked raw, masked compressed, local steps,
//! cohort sampling). Plus the robustness contract: malformed, truncated
//! and oversized frames error loudly and never hang the server.

use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

use fedeff::config::Spec;
use fedeff::metrics::RunRecord;
use fedeff::wire::net::{run_fleet, run_in_process, NetServer};

/// Run `toml` once over TCP loopback (server + in-thread fleet) and
/// once in-process; return both records.
fn networked_vs_inproc(toml: &str) -> (RunRecord, RunRecord) {
    let spec = Spec::parse(toml).expect("test spec parses");
    let server = NetServer::bind("tcp:127.0.0.1:0").expect("bind loopback");
    let addr = server.local_addr().expect("resolved address");
    let net = std::thread::scope(|scope| {
        let fleet = {
            let spec = &spec;
            let addr = addr.clone();
            scope.spawn(move || run_fleet(&addr, spec))
        };
        let rec = server.serve(&spec, &mut |_| {}).expect("networked serve");
        fleet.join().expect("fleet thread").expect("fleet run");
        rec
    });
    let inproc = run_in_process(&spec, &mut |_| {}).expect("in-process run");
    (net, inproc)
}

fn assert_bitwise_equal(net: &RunRecord, inproc: &RunRecord) {
    assert_eq!(net.rounds.len(), inproc.rounds.len(), "eval round counts differ");
    assert!(!net.rounds.is_empty(), "run produced no eval rounds");
    for (a, b) in net.rounds.iter().zip(&inproc.rounds) {
        assert_eq!(a.round, b.round);
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "round {}: networked loss {} != in-process loss {}",
            a.round,
            a.loss,
            b.loss
        );
        assert_eq!(a.bits_up, b.bits_up, "round {}: booked uplink bits differ", a.round);
        assert_eq!(a.bits_down, b.bits_down, "round {}: booked downlink bits differ", a.round);
        assert_eq!(
            a.comm_cost.to_bits(),
            b.comm_cost.to_bits(),
            "round {}: comm cost differs",
            a.round
        );
    }
    assert_eq!(net.mask_nnz, inproc.mask_nnz, "mask support sizes differ");
}

#[test]
fn gd_topk_over_tcp_matches_inproc_bitwise() {
    let (net, inproc) = networked_vs_inproc(
        r#"
[experiment]
name = "net-gd-topk"
rounds = 20
eval_every = 5
seed = 7

[dataset]
clients = 8

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 12
"#,
    );
    assert_bitwise_equal(&net, &inproc);
    // compression actually happened: bits stay far below dense
    let last = net.rounds.last().unwrap();
    assert!(last.bits_up > 0 && last.bits_up < 20 * 32 * 112);
}

#[test]
fn fedavg_sampled_randk_over_tcp_matches_inproc_bitwise() {
    // local steps (LocalSgd payload) + the default nice sampler
    // (changing cohorts each round) + rand-k's per-client rng streams
    let (net, inproc) = networked_vs_inproc(
        r#"
[experiment]
name = "net-fedavg-randk"
rounds = 18
eval_every = 6
seed = 3

[dataset]
clients = 12

[algorithm]
kind = "fedavg"
local_steps = 3
lr = 0.1

[compressor]
up = "rand-k"
k = 16
"#,
    );
    assert_bitwise_equal(&net, &inproc);
}

#[test]
fn fedprox_srandk_over_tcp_matches_inproc_bitwise() {
    // proximal local steps (prox_mu travels in the ROUND frame)
    let (net, inproc) = networked_vs_inproc(
        r#"
[experiment]
name = "net-fedprox-srandk"
rounds = 12
eval_every = 4
seed = 11

[dataset]
clients = 10

[algorithm]
kind = "fedprox"
local_steps = 2
lr = 0.1
mu_prox = 0.05

[compressor]
up = "srand-k"
k = 10
"#,
    );
    assert_bitwise_equal(&net, &inproc);
}

#[test]
fn masked_compressed_uplink_over_tcp_matches_inproc_bitwise() {
    // global sparsity mask + top-k within the support: the
    // MaskedSparse layout with support-relative packed indices
    let (net, inproc) = networked_vs_inproc(
        r#"
[experiment]
name = "net-masked-topk"
rounds = 16
eval_every = 4
seed = 5

[dataset]
clients = 8

[algorithm]
kind = "fedavg"
local_steps = 2
lr = 0.1

[compressor]
up = "top-k"
k = 8

[sparsity]
method = "magnitude"
sparsity = 0.5
"#,
    );
    assert_bitwise_equal(&net, &inproc);
    assert!(net.mask_nnz.is_some(), "masked run must report its support");
}

#[test]
fn masked_raw_uplink_over_tcp_matches_inproc_bitwise() {
    // mask with no compressor: the MaskedRaw layout (values only,
    // 32 bits per support coordinate)
    let (net, inproc) = networked_vs_inproc(
        r#"
[experiment]
name = "net-masked-raw"
rounds = 12
eval_every = 4
seed = 9

[dataset]
clients = 6

[algorithm]
kind = "gd"
lr = 0.5

[sparsity]
method = "magnitude"
sparsity = 0.6
"#,
    );
    assert_bitwise_equal(&net, &inproc);
}

// -------------------------------------------------------------------
// robustness: broken peers error loudly, never hang or panic
// -------------------------------------------------------------------

const BROKEN_PEER_SPEC: &str = r#"
[experiment]
name = "net-broken"
rounds = 5
seed = 1

[dataset]
clients = 1

[algorithm]
kind = "gd"
lr = 0.5

[compressor]
up = "top-k"
k = 4
"#;

/// Bind a short-timeout server and run `peer` against it on a raw TCP
/// socket; the serve must return an error (and must return at all).
fn serve_against_broken_peer(peer: impl FnOnce(&mut TcpStream) + Send) -> String {
    let spec = Spec::parse(BROKEN_PEER_SPEC).unwrap();
    let mut server = NetServer::bind("tcp:127.0.0.1:0").unwrap();
    server.timeout = Duration::from_millis(500);
    let addr = server.local_addr().unwrap();
    let hostport = addr.strip_prefix("tcp:").unwrap().to_string();
    std::thread::scope(|scope| {
        scope.spawn(move || {
            let mut s = TcpStream::connect(&hostport).expect("connect to test server");
            peer(&mut s);
            // hold the socket open briefly so the server error comes
            // from frame validation, not a racing disconnect
            std::thread::sleep(Duration::from_millis(200));
        });
        let err = server
            .serve(&spec, &mut |_| {})
            .expect_err("server must reject the broken peer");
        format!("{err:#}")
    })
}

fn frame(kind: u8, payload: &[u8]) -> Vec<u8> {
    let mut f = Vec::with_capacity(5 + payload.len());
    f.extend_from_slice(&(payload.len() as u32 + 1).to_le_bytes());
    f.push(kind);
    f.extend_from_slice(payload);
    f
}

#[test]
fn garbage_first_frame_errors_loudly() {
    let err = serve_against_broken_peer(|s| {
        s.write_all(&frame(0xAB, &[1, 2, 3])).unwrap();
    });
    assert!(err.contains("HELLO"), "unexpected error: {err}");
}

#[test]
fn oversized_frame_is_rejected() {
    let err = serve_against_broken_peer(|s| {
        // header claims 1 GiB; the length check must fire before any
        // allocation or read of that size
        s.write_all(&(1u32 << 30).to_le_bytes()).unwrap();
        s.write_all(&[1]).unwrap();
    });
    assert!(err.contains("oversized"), "unexpected error: {err}");
}

#[test]
fn truncated_frame_times_out_with_an_error() {
    let err = serve_against_broken_peer(|s| {
        // header promises 64 payload bytes that never arrive; the read
        // timeout must surface as an error instead of hanging
        s.write_all(&65u32.to_le_bytes()).unwrap();
        s.write_all(&[1]).unwrap();
    });
    assert!(!err.is_empty());
}

#[test]
fn malformed_msg_after_valid_hello_errors_loudly() {
    let err = serve_against_broken_peer(|s| {
        // a correct HELLO for client 0 of 1 (dim 112 = mushrooms) ...
        let mut hello = Vec::new();
        hello.extend_from_slice(&0u32.to_le_bytes());
        hello.extend_from_slice(&1u32.to_le_bytes());
        hello.extend_from_slice(&112u32.to_le_bytes());
        s.write_all(&frame(1, &hello)).unwrap();
        // ... then an MSG whose body length cannot match any layout
        let mut msg = Vec::new();
        msg.extend_from_slice(&0u32.to_le_bytes()); // round
        msg.push(0); // channel
        msg.push(0); // layout: sparse
        msg.extend_from_slice(&4u32.to_le_bytes()); // k = 4
        msg.extend_from_slice(&[0xFF; 3]); // 3 bytes << the 20 required
        s.write_all(&frame(3, &msg)).unwrap();
    });
    assert!(err.contains("decoding client 0"), "unexpected error: {err}");
}

#[test]
fn duplicate_client_id_is_rejected() {
    let spec = Spec::parse(
        r#"
[experiment]
name = "net-dup"
rounds = 3
seed = 1

[dataset]
clients = 2

[algorithm]
kind = "gd"

[compressor]
up = "top-k"
k = 4
"#,
    )
    .unwrap();
    let mut server = NetServer::bind("tcp:127.0.0.1:0").unwrap();
    server.timeout = Duration::from_millis(500);
    let addr = server.local_addr().unwrap();
    let hostport = addr.strip_prefix("tcp:").unwrap().to_string();
    std::thread::scope(|scope| {
        scope.spawn(move || {
            let mut hello = Vec::new();
            hello.extend_from_slice(&0u32.to_le_bytes());
            hello.extend_from_slice(&2u32.to_le_bytes());
            hello.extend_from_slice(&112u32.to_le_bytes());
            let f = frame(1, &hello);
            // two sockets both claiming client id 0
            let mut a = TcpStream::connect(&hostport).unwrap();
            a.write_all(&f).unwrap();
            let mut b = TcpStream::connect(&hostport).unwrap();
            b.write_all(&f).unwrap();
            std::thread::sleep(Duration::from_millis(200));
        });
        let err = server.serve(&spec, &mut |_| {}).expect_err("duplicate id must be rejected");
        assert!(format!("{err:#}").contains("twice"), "unexpected error: {err:#}");
    });
}
