//! Wire codec properties (DESIGN.md §Wire): for every registry message
//! kind, `decode(encode(x)) == x` exactly (f32 raw bits preserved) and
//! `encode(x).bit_len()` equals the bits the compressor quoted — the
//! number the [`fedeff::coordinator::CommLedger`] books. Plus the
//! robustness contract: random and bit-flipped byte streams must never
//! panic a decoder (they either decode to something valid or return a
//! loud error).

use fedeff::compress::permk::PermK;
use fedeff::compress::quantize::Qsgd;
use fedeff::compress::randk::RandK;
use fedeff::compress::topk::TopK;
use fedeff::compress::{client_rng, sparse_bits, Compressor, Identity, SparseVec};
use fedeff::wire::bits::{BitReader, BitWriter};
use fedeff::wire::codec;

/// Deterministic test vector with mixed signs and magnitudes.
fn vector(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = fedeff::rng(seed);
    (0..d).map(|_| rng.f32_range(-2.0, 2.0)).collect()
}

fn assert_same_pairs(kind: &str, got: &SparseVec, want: &SparseVec) {
    assert_eq!(got.idx, want.idx, "{kind}: decoded indices differ");
    assert_eq!(got.val.len(), want.val.len(), "{kind}: decoded pair count differs");
    for (j, (g, w)) in got.val.iter().zip(&want.val).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{kind}: value {j} not bitwise-identical");
    }
}

// -------------------------------------------------------------------
// sparse: Top-K / Rand-K / sRand-K native messages
// -------------------------------------------------------------------

#[test]
fn sparse_codec_roundtrips_and_matches_ledger() {
    // dims deliberately include non-powers-of-two and k == d
    for &d in &[2usize, 7, 23, 100, 128, 1000] {
        for &k in &[1usize, 3, 8, d] {
            let comps: Vec<(&str, Box<dyn Compressor>)> = vec![
                ("top-k", Box::new(TopK::new(k))),
                ("rand-k", Box::new(RandK::unbiased(k))),
                ("srand-k", Box::new(RandK::scaled(k))),
            ];
            for (name, comp) in comps {
                let x = vector(d, 0xC0DE + d as u64 + k as u64);
                let mut rng = client_rng(7, 3, 1, 0);
                let mut sv = SparseVec::default();
                let bits = comp
                    .compress_sparse(&x, &mut sv, &mut rng)
                    .expect("sparsifiers have a sparse form");
                assert_eq!(bits, sparse_bits(k.min(d), d), "{name}: quote (d={d}, k={k})");

                let mut w = BitWriter::new();
                codec::encode_sparse(&sv, &mut w).unwrap();
                assert_eq!(w.bit_len(), bits, "{name}: codec bits != ledger bits (d={d}, k={k})");
                let bytes = w.finish().to_vec();
                assert_eq!(bytes.len() as u64, bits.div_ceil(8));

                let mut r = BitReader::new(&bytes);
                let mut back = SparseVec::default();
                codec::decode_sparse(&mut r, d, sv.len(), &mut back).unwrap();
                assert_same_pairs(name, &back, &sv);
            }
        }
    }
}

// -------------------------------------------------------------------
// masked payloads: raw support values and compressed-within-support
// -------------------------------------------------------------------

#[test]
fn masked_raw_codec_roundtrips_at_32_bits_per_nnz() {
    for &d in &[6usize, 23, 112] {
        // every third coordinate, and the nnz == 1 edge
        for sup in [
            (0..d as u32).step_by(3).collect::<Vec<u32>>(),
            vec![(d - 1) as u32],
        ] {
            let x = vector(d, 0xA5 + d as u64);
            let mut sv = SparseVec::default();
            sv.clear(d);
            for &j in &sup {
                sv.push(j, x[j as usize]);
            }
            let mut w = BitWriter::new();
            codec::encode_masked_raw(&sv, &sup, &mut w).unwrap();
            assert_eq!(w.bit_len(), 32 * sup.len() as u64, "masked raw is 32 bits per nnz");
            let bytes = w.finish().to_vec();
            let mut back = SparseVec::default();
            codec::decode_masked_raw(&mut BitReader::new(&bytes), d, &sup, &mut back).unwrap();
            assert_same_pairs("masked-raw", &back, &sv);
        }
    }
}

#[test]
fn masked_sparse_codec_roundtrips_with_support_relative_indices() {
    for &d in &[23usize, 112, 300] {
        let sup: Vec<u32> = (0..d as u32).filter(|j| j % 4 != 1).collect();
        let nnz = sup.len();
        for &k in &[1usize, 5, nnz] {
            for (name, comp) in [
                ("top-k", Box::new(TopK::new(k)) as Box<dyn Compressor>),
                ("rand-k", Box::new(RandK::unbiased(k))),
            ] {
                // replicate the fused emit path: gather the support,
                // compress the compacted vector, remap to global indices
                let x = vector(d, 0xF00D + d as u64 + k as u64);
                let gathered: Vec<f32> = sup.iter().map(|&j| x[j as usize]).collect();
                let mut rng = client_rng(11, 5, 2, 0);
                let mut compact = SparseVec::default();
                let bits = comp.compress_sparse(&gathered, &mut compact, &mut rng).unwrap();
                assert_eq!(bits, sparse_bits(k.min(nnz), nnz), "{name}: support-domain quote");
                let mut global = SparseVec::default();
                global.clear(d);
                for (&c, &v) in compact.idx.iter().zip(&compact.val) {
                    global.push(sup[c as usize], v);
                }

                let mut w = BitWriter::new();
                codec::encode_masked_sparse(&global, &sup, &mut w).unwrap();
                assert_eq!(w.bit_len(), bits, "{name}: codec bits != ledger bits over support");
                let bytes = w.finish().to_vec();
                let mut back = SparseVec::default();
                codec::decode_masked_sparse(
                    &mut BitReader::new(&bytes),
                    d,
                    &sup,
                    global.len(),
                    &mut back,
                )
                .unwrap();
                assert_same_pairs(name, &back, &global);
            }
        }
    }
}

// -------------------------------------------------------------------
// QSGD: the encoder IS the quantizer
// -------------------------------------------------------------------

#[test]
fn qsgd_codec_replicates_the_compressor_exactly() {
    for &levels in &[1u32, 2, 4, 7, 15, 33] {
        for &len in &[1usize, 5, 23, 112] {
            let q = Qsgd::new(levels);
            let x = vector(len, 0xBEEF + levels as u64 + len as u64);
            let mut compressed = vec![0.0f32; len];
            let mut rng_comp = client_rng(3, 9, 4, 0);
            let mut rng_codec = client_rng(3, 9, 4, 0);
            let bits = q.compress(&x, &mut compressed, &mut rng_comp);

            let mut w = BitWriter::new();
            codec::qsgd_encode(levels, &x, &mut rng_codec, &mut w);
            assert_eq!(
                w.bit_len(),
                bits,
                "qsgd codec bits != quote (levels={levels}, len={len})"
            );
            assert_eq!(
                bits,
                32 + len as u64 * codec::qsgd_entry_width(levels) as u64,
                "entry width mirrors the compressor formula"
            );
            // identical rng draw counts: both streams must now agree
            assert_eq!(rng_comp.next_u64(), rng_codec.next_u64(), "rng streams diverged");

            let bytes = w.finish().to_vec();
            let mut back = Vec::new();
            codec::qsgd_decode(&mut BitReader::new(&bytes), levels, len, &mut back).unwrap();
            assert_eq!(back.len(), len);
            for (j, (b, c)) in back.iter().zip(&compressed).enumerate() {
                // numerically identical everywhere; level-0 entries are
                // canonicalized to +0.0 (compress may carry -0.0, which
                // is == and scatter-invisible)
                assert_eq!(b, c, "entry {j} differs (levels={levels})");
                if *c != 0.0 {
                    assert_eq!(b.to_bits(), c.to_bits(), "entry {j} not bitwise (levels={levels})");
                }
            }
        }
    }
}

#[test]
fn qsgd_codec_handles_the_zero_vector_without_rng_draws() {
    let levels = 4u32;
    let q = Qsgd::new(levels);
    let x = vec![0.0f32; 17];
    let mut compressed = vec![1.0f32; 17];
    let mut rng_comp = fedeff::rng(42);
    let mut rng_codec = fedeff::rng(42);
    let bits = q.compress(&x, &mut compressed, &mut rng_comp);
    let mut w = BitWriter::new();
    codec::qsgd_encode(levels, &x, &mut rng_codec, &mut w);
    assert_eq!(w.bit_len(), bits);
    assert_eq!(rng_comp.next_u64(), rng_codec.next_u64(), "zero vector must not draw");
    let bytes = w.finish().to_vec();
    let mut back = Vec::new();
    codec::qsgd_decode(&mut BitReader::new(&bytes), levels, 17, &mut back).unwrap();
    assert_eq!(back, compressed);
}

// -------------------------------------------------------------------
// PermK: seed travels, block is re-derived
// -------------------------------------------------------------------

#[test]
fn permk_codec_roundtrips_every_worker_block() {
    let n = 4usize;
    for &d in &[13usize, 64, 100] {
        for worker in 0..n {
            let comp = PermK::new(n, worker, 0xFEED_F00D ^ d as u64);
            let x = vector(d, 0x9 + d as u64 + worker as u64);
            let mut rng = client_rng(1, 2, worker, 0);
            let mut sv = SparseVec::default();
            let bits = comp.compress_sparse(&x, &mut sv, &mut rng).unwrap();
            assert_eq!(bits, 64 + 32 * sv.len() as u64, "PermK quote: seed + kept values");

            let mut w = BitWriter::new();
            codec::permk_encode(&comp, &sv, &mut w).unwrap();
            assert_eq!(w.bit_len(), bits, "PermK codec bits != quote (d={d}, worker={worker})");
            let bytes = w.finish().to_vec();
            let mut back = SparseVec::default();
            codec::permk_decode(&mut BitReader::new(&bytes), n, worker, d, &mut back).unwrap();
            assert_same_pairs("perm-k", &back, &sv);
        }
    }
}

// -------------------------------------------------------------------
// Identity: the dense run
// -------------------------------------------------------------------

#[test]
fn dense_codec_roundtrips_identity_messages() {
    for &d in &[1usize, 23, 112] {
        let x = vector(d, 0x1D + d as u64);
        let mut out = vec![0.0f32; d];
        let bits = Identity.compress(&x, &mut out, &mut fedeff::rng(0));
        assert_eq!(bits, 32 * d as u64);
        let mut w = BitWriter::new();
        codec::encode_dense(&x, &mut w);
        assert_eq!(w.bit_len(), bits, "dense codec bits != ledger bits");
        let bytes = w.finish().to_vec();
        let mut back = Vec::new();
        codec::decode_dense(&mut BitReader::new(&bytes), d, &mut back).unwrap();
        for (b, v) in back.iter().zip(&x) {
            assert_eq!(b.to_bits(), v.to_bits());
        }
    }
}

// -------------------------------------------------------------------
// robustness: garbage in, errors (never panics) out
// -------------------------------------------------------------------

/// Throw random byte strings at every decoder: each call must return
/// (Ok with validated contents, or Err) — never panic.
#[test]
fn decoders_survive_random_bytes() {
    let mut rng = fedeff::rng(0xDEAD);
    let sup: Vec<u32> = (0..40u32).step_by(2).collect();
    for _ in 0..500 {
        let len = rng.below(64);
        let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let d = 2 + rng.below(200);
        let k = 1 + rng.below(d);
        let mut sv = SparseVec::default();
        if let Ok(()) = codec::decode_sparse(&mut BitReader::new(&bytes), d, k, &mut sv) {
            assert!(sv.idx.iter().all(|&i| (i as usize) < d), "accepted out-of-range index");
        }
        let _ = codec::decode_masked_raw(&mut BitReader::new(&bytes), 40, &sup, &mut sv);
        let kk = 1 + rng.below(sup.len());
        if let Ok(()) =
            codec::decode_masked_sparse(&mut BitReader::new(&bytes), 40, &sup, kk, &mut sv)
        {
            assert!(sv.idx.iter().all(|&i| sup.contains(&i)), "accepted index outside support");
        }
        let mut dense = Vec::new();
        let _ = codec::qsgd_decode(&mut BitReader::new(&bytes), 4, 16, &mut dense);
        let _ = codec::decode_dense(&mut BitReader::new(&bytes), 64, &mut dense);
        let _ = codec::permk_decode(&mut BitReader::new(&bytes), 4, 1, d, &mut sv);
    }
}

/// Flip every bit of a valid sparse encoding in turn: the decoder must
/// either reject the corrupted stream or produce an in-range result.
#[test]
fn bit_flips_never_panic_the_sparse_decoder() {
    let d = 100usize;
    let comp = TopK::new(8);
    let x = vector(d, 0xF11);
    let mut sv = SparseVec::default();
    comp.compress_sparse(&x, &mut sv, &mut fedeff::rng(5)).unwrap();
    let mut w = BitWriter::new();
    codec::encode_sparse(&sv, &mut w).unwrap();
    let clean = w.finish().to_vec();
    for bit in 0..clean.len() * 8 {
        let mut bytes = clean.clone();
        bytes[bit / 8] ^= 1 << (bit % 8);
        let mut back = SparseVec::default();
        if let Ok(()) = codec::decode_sparse(&mut BitReader::new(&bytes), d, sv.len(), &mut back) {
            assert!(back.idx.iter().all(|&i| (i as usize) < d));
            assert_eq!(back.len(), sv.len());
        }
    }
}

/// Truncating a valid encoding at every byte boundary errors loudly.
#[test]
fn truncation_errors_loudly_in_every_codec() {
    let d = 64usize;
    let x = vector(d, 0x7AB);
    let comp = TopK::new(9);
    let mut sv = SparseVec::default();
    comp.compress_sparse(&x, &mut sv, &mut fedeff::rng(6)).unwrap();
    let mut w = BitWriter::new();
    codec::encode_sparse(&sv, &mut w).unwrap();
    let clean = w.finish().to_vec();
    // any strict prefix is missing at least one trailing value bit
    for cut in 0..clean.len().saturating_sub(1) {
        let mut back = SparseVec::default();
        assert!(
            codec::decode_sparse(&mut BitReader::new(&clean[..cut]), d, sv.len(), &mut back)
                .is_err(),
            "prefix of {cut} bytes decoded silently"
        );
    }
}

// -------------------------------------------------------------------
// anchor delta: the downlink's exact changed-coordinate patch
// -------------------------------------------------------------------

/// Random change patterns over non-power-of-two dims: patching the old
/// anchor with the decoded delta reproduces the new anchor bitwise, at
/// exactly the bits the ledger books.
#[test]
fn anchor_delta_roundtrips_random_change_patterns() {
    let mut rng = fedeff::rng(0xD17A);
    for &d in &[2usize, 7, 23, 100, 128, 1000] {
        for trial in 0..8u64 {
            let old = vector(d, 0x01D + d as u64 + trial);
            let mut new = old.clone();
            let mut coords: Vec<u32> = Vec::new();
            for j in 0..d {
                if rng.below(3) == 0 {
                    let v = rng.f32_range(-2.0, 2.0);
                    if v.to_bits() != old[j].to_bits() {
                        new[j] = v;
                        coords.push(j as u32);
                    }
                }
            }
            let m = coords.len();
            let mut w = BitWriter::new();
            codec::encode_anchor_delta(&coords, &new, &mut w).unwrap();
            assert_eq!(
                w.bit_len(),
                codec::anchor_delta_bits(m, d),
                "delta bits formula (d={d}, m={m})"
            );
            let bytes = w.finish().to_vec();
            assert_eq!(bytes.len() as u64, codec::anchor_delta_bits(m, d).div_ceil(8));

            let mut patched = old.clone();
            let mut r = BitReader::new(&bytes);
            codec::decode_anchor_delta(&mut r, m, &mut patched).unwrap();
            r.expect_zero_pad().unwrap();
            for (j, (p, n)) in patched.iter().zip(&new).enumerate() {
                assert_eq!(p.to_bits(), n.to_bits(), "coord {j} not bitwise (d={d})");
            }
        }
    }
}

/// The nnz edges: an empty delta (nothing changed), a single changed
/// coordinate, and every coordinate changed — including d = 1.
#[test]
fn anchor_delta_handles_empty_single_and_full_changes() {
    for &d in &[1usize, 5, 97] {
        let old = vector(d, 0xE11 + d as u64);
        let new = vector(d, 0xF22 + d as u64);
        let patterns: [Vec<u32>; 3] =
            [Vec::new(), vec![(d - 1) as u32], (0..d as u32).collect()];
        for coords in patterns {
            let m = coords.len();
            let mut w = BitWriter::new();
            codec::encode_anchor_delta(&coords, &new, &mut w).unwrap();
            assert_eq!(w.bit_len(), codec::anchor_delta_bits(m, d));
            let bytes = w.finish().to_vec();
            let mut patched = old.clone();
            let mut r = BitReader::new(&bytes);
            codec::decode_anchor_delta(&mut r, m, &mut patched).unwrap();
            r.expect_zero_pad().unwrap();
            for j in 0..d {
                let want = if coords.contains(&(j as u32)) { new[j] } else { old[j] };
                assert_eq!(patched[j].to_bits(), want.to_bits(), "coord {j} (d={d}, m={m})");
            }
        }
    }
}

/// Both codec halves reject malformed coordinate lists loudly:
/// duplicates, descending order, out-of-range indices.
#[test]
fn anchor_delta_rejects_unsorted_and_out_of_range_coords() {
    let new = vector(10, 0xBAD);
    let mut w = BitWriter::new();
    assert!(codec::encode_anchor_delta(&[3, 3], &new, &mut w).is_err(), "duplicate index");
    let mut w = BitWriter::new();
    assert!(codec::encode_anchor_delta(&[5, 2], &new, &mut w).is_err(), "descending indices");
    let mut w = BitWriter::new();
    assert!(codec::encode_anchor_delta(&[10], &new, &mut w).is_err(), "index == dim");

    // a hand-packed descending stream must be rejected by the decoder
    let mut w = BitWriter::new();
    codec::encode_anchor_delta(&[7], &new, &mut w).unwrap();
    codec::encode_anchor_delta(&[2], &new, &mut w).unwrap();
    let bytes = w.finish().to_vec();
    let mut anchor = new.clone();
    assert!(
        codec::decode_anchor_delta(&mut BitReader::new(&bytes), 2, &mut anchor).is_err(),
        "decoder accepted descending indices"
    );
}

// -------------------------------------------------------------------
// reconnect backoff (DESIGN.md §Faults)
// -------------------------------------------------------------------

/// The client (re)connect backoff is a capped exponential with
/// deterministic jitter: attempt `k` sleeps `min(10ms << k, 640ms)`
/// scaled by a factor in `[0.5, 1.0)` drawn from a seed-keyed stream —
/// so the same seed replays the same schedule, different seeds spread a
/// retry storm out, and no delay ever exceeds the cap or undershoots
/// half the exponential.
#[test]
fn backoff_schedule_is_capped_jittered_exponential_per_seed() {
    use fedeff::wire::net::Backoff;
    use std::time::Duration;

    let schedule = |seed: u64, n: usize| -> Vec<Duration> {
        let mut b = Backoff::new(seed);
        (0..n).map(|_| b.next_delay()).collect()
    };
    // deterministic per seed, distinct across seeds
    assert_eq!(schedule(3, 12), schedule(3, 12));
    assert_ne!(schedule(3, 12), schedule(4, 12));

    // every delay lands in [exp/2, exp) of the capped exponential
    for seed in 0..64u64 {
        let mut b = Backoff::new(seed);
        for attempt in 0..12u32 {
            let exp = (10u64 << attempt.min(6)).min(640);
            let d = b.next_delay().as_nanos() as u64;
            let (lo, hi) = (exp * 1_000_000 / 2, exp * 1_000_000);
            assert!(
                d >= lo && d < hi,
                "seed {seed} attempt {attempt}: {d} ns outside [{lo}, {hi})"
            );
        }
    }

    // reset restarts the exponential but keeps the jitter stream moving
    let mut b = Backoff::new(9);
    let first = b.next_delay();
    for _ in 0..8 {
        b.next_delay();
    }
    b.reset();
    let after = b.next_delay();
    assert!(after.as_millis() < 10, "reset delay {after:?} not back at the 10ms base");
    assert_ne!(first, after, "jitter stream repeated after reset");
}

/// Fuzzed and truncated delta bodies error loudly, never panic, and an
/// `Ok` decode can only have written in-range coordinates.
#[test]
fn anchor_delta_decoder_survives_random_bytes_and_truncation() {
    let mut rng = fedeff::rng(0xF0DD);
    for _ in 0..500 {
        let len = rng.below(64);
        let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let d = 2 + rng.below(200);
        let m = 1 + rng.below(d);
        let mut anchor = vec![0.0f32; d];
        let _ = codec::decode_anchor_delta(&mut BitReader::new(&bytes), m, &mut anchor);
    }

    // every strict byte prefix of a valid delta is missing needed bits
    let d = 100usize;
    let new = vector(d, 0x717);
    let coords: Vec<u32> = (0..d as u32).step_by(7).collect();
    let mut w = BitWriter::new();
    codec::encode_anchor_delta(&coords, &new, &mut w).unwrap();
    let clean = w.finish().to_vec();
    for cut in 0..clean.len().saturating_sub(1) {
        let mut anchor = vec![0.0f32; d];
        assert!(
            codec::decode_anchor_delta(&mut BitReader::new(&clean[..cut]), coords.len(), &mut anchor)
                .is_err(),
            "prefix of {cut} bytes decoded silently"
        );
    }
}
