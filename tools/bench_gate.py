#!/usr/bin/env python3
"""CI bench regression gate (DESIGN.md §Benchmarks, ROADMAP item 5).

Compares the committed `BENCH_algorithms.json` medians against a fresh
`FEDEFF_BENCH_QUICK=1` run (which writes `BENCH_algorithms.json.quick`
next to it) and fails if any *measured* row regressed by more than the
threshold. Rows whose committed name carries an `@seeded` (or other
`@...`) suffix are projections, not measurements, so they are reported
but never gated; rows only present on one side are reported too.

Quick mode runs one iteration on shared CI hardware, so the threshold
is deliberately loose: the gate catches "this path got 2x slower"
rot, not single-digit drift.

Usage:
    python3 tools/bench_gate.py [--committed BENCH_algorithms.json]
                                [--quick BENCH_algorithms.json.quick]
                                [--threshold 1.25]

Exit status: 0 = no gated regression, 1 = regression, 2 = bad input.
"""

import argparse
import json
import sys


def load_entries(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench-gate: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    entries = doc.get("entries")
    if not isinstance(entries, list) or not entries:
        print(f"bench-gate: {path} has no 'entries' list", file=sys.stderr)
        sys.exit(2)
    out = {}
    for e in entries:
        name = e.get("name")
        ns = e.get("ns_per_iter")
        if not isinstance(name, str) or not isinstance(ns, (int, float)) or ns <= 0:
            print(f"bench-gate: malformed entry in {path}: {e!r}", file=sys.stderr)
            sys.exit(2)
        out[name] = ns
    return out


def base_name(name):
    """Strip the '@seeded' / '@pre-PR2' style provenance suffix."""
    return name.split("@", 1)[0]


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--committed", default="BENCH_algorithms.json")
    ap.add_argument("--quick", default="BENCH_algorithms.json.quick")
    ap.add_argument(
        "--threshold",
        type=float,
        default=1.25,
        help="fail when quick ns_per_iter > committed * THRESHOLD (default 1.25)",
    )
    args = ap.parse_args()

    committed = load_entries(args.committed)
    quick_raw = load_entries(args.quick)
    # the quick writer never emits provenance suffixes, but strip them
    # anyway so the gate survives a future tagging scheme
    quick = {base_name(k): v for k, v in quick_raw.items()}

    failures = []
    gated = skipped = 0
    for name, base_ns in sorted(committed.items()):
        seeded = "@" in name
        quick_ns = quick.get(base_name(name))
        if quick_ns is None:
            print(f"  absent  {name}: no quick measurement (row skipped)")
            skipped += 1
            continue
        ratio = quick_ns / base_ns
        if seeded:
            print(f"  seeded  {name}: quick {quick_ns:.0f} ns vs projection ({ratio:.2f}x, not gated)")
            skipped += 1
            continue
        gated += 1
        verdict = "ok" if ratio <= args.threshold else "REGRESSED"
        print(f"  {verdict:>8}  {name}: {base_ns:.0f} -> {quick_ns:.0f} ns ({ratio:.2f}x)")
        if ratio > args.threshold:
            failures.append((name, ratio))

    for name in sorted(set(quick) - {base_name(n) for n in committed}):
        print(f"  new     {name}: quick-only row (commit a median or a seeded projection)")

    print(
        f"bench-gate: {gated} rows gated at {args.threshold:.2f}x, "
        f"{skipped} skipped, {len(failures)} regressed"
    )
    if failures:
        for name, ratio in failures:
            print(f"bench-gate: REGRESSION {name} at {ratio:.2f}x", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
